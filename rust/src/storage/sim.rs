//! Simulated storage devices: an I/O cost model layered over any engine.
//!
//! The paper's Figures 10–13 are shaped by device economics we cannot
//! reproduce literally (a Dell H700 RAID-6 array of 11 SATA drives vs. a
//! RAID-0 pair of OCZ Vertex4 SSDs). [`SimulatedStore`] wraps a content
//! engine (usually [`super::MemStore`]) and charges each operation wall
//! clock according to a [`DeviceProfile`]:
//!
//! * every random operation pays a positioning latency (`seek`);
//! * transfers pay `bytes / bandwidth`;
//! * a contiguous run read pays ONE seek plus streaming for the whole run
//!   — this is precisely the benefit the Morton layout buys (§5);
//! * ops-per-second is capped (`iops`) — the paper's SSD nodes "realize
//!   20K IOPS of the theoretical 120K" (§4.1);
//! * at most `parallelism` operations progress concurrently (spindle /
//!   channel count) — excess callers queue, which produces the saturation
//!   and decline of Figure 11.
//!
//! `time_scale` shrinks all charged latencies by a constant factor so the
//! benches finish quickly; every reported throughput is scaled back up by
//! the caller (the *ratios* between configurations are scale-invariant).
//!
//! The store also carries a [`FaultInjector`]: deterministic crash and
//! transient-error hooks that make node death a reproducible test input
//! instead of a prayer (DESIGN.md §10). Faults are checked *before* the
//! inner engine is touched, so an injected failure never half-applies a
//! batch.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::storage::{Blob, Engine, IoStats, StorageEngine};
use crate::util::Rng;
use crate::{Error, Result};

/// Cost model for one device class.
#[derive(Clone, Copy, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Positioning latency per random read, microseconds.
    pub read_seek_us: f64,
    /// Positioning latency per random write, microseconds. RAID-6 pays a
    /// parity read-modify-write penalty on small writes, so this exceeds
    /// `read_seek_us` on the disk-array profile.
    pub write_seek_us: f64,
    /// Streaming read bandwidth, MB/s.
    pub read_mbps: f64,
    /// Streaming write bandwidth, MB/s.
    pub write_mbps: f64,
    /// Max random operations per second (0 = uncapped / seek-bound).
    pub iops: f64,
    /// Concurrent operations the device sustains before queueing.
    pub parallelism: usize,
}

impl DeviceProfile {
    /// The paper's Database-node storage: Dell H700 RAID-6 over 11 SATA
    /// drives (§4.1/§5). Good streaming, seek-bound random reads, and a
    /// painful small-write penalty from RAID-6 parity.
    pub fn hdd_array() -> Self {
        DeviceProfile {
            name: "raid6-sata",
            read_seek_us: 8_000.0,
            write_seek_us: 16_000.0, // parity read-modify-write
            read_mbps: 350.0,
            write_mbps: 250.0,
            iops: 0.0,
            parallelism: 10, // spindles minus parity overhead
        }
    }

    /// The paper's SSD-node storage: two OCZ Vertex4 in RAID-0, realizing
    /// 20K IOPS behind a weak controller (§4.1).
    pub fn ssd_raid0() -> Self {
        DeviceProfile {
            name: "ssd-vertex4",
            read_seek_us: 120.0,
            write_seek_us: 150.0,
            read_mbps: 450.0,
            write_mbps: 380.0,
            iops: 20_000.0,
            parallelism: 16,
        }
    }

    /// A zero-cost profile for fault-injection tests: no seeks, effectively
    /// infinite bandwidth, no IOPS cap. Wrapping a [`super::MemStore`] in
    /// `instant` buys the fault hooks without paying simulated latency, so
    /// failover tests run in microseconds.
    pub fn instant() -> Self {
        DeviceProfile {
            name: "instant",
            read_seek_us: 0.0,
            write_seek_us: 0.0,
            read_mbps: 1e12,
            write_mbps: 1e12,
            iops: 0.0,
            parallelism: 1 << 16,
        }
    }

    /// Cost in microseconds of a random read of `bytes`.
    fn read_cost_us(&self, bytes: u64) -> f64 {
        self.read_seek_us + bytes as f64 / self.read_mbps
    }

    /// Cost in microseconds of a random write of `bytes`.
    fn write_cost_us(&self, bytes: u64) -> f64 {
        self.write_seek_us + bytes as f64 / self.write_mbps
    }
    // (1 byte / (MB/s)) == 1 µs/MB == bytes/mbps µs — the units line up
    // because 1 MB/s moves one byte per microsecond.
}

/// Counting semaphore (no external deps available offline).
struct Semaphore {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    fn new(n: usize) -> Self {
        Semaphore { permits: Mutex::new(n), cv: Condvar::new() }
    }

    fn acquire(&self) {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
    }

    fn release(&self) {
        *self.permits.lock().unwrap() += 1;
        self.cv.notify_one();
    }
}

/// Deterministic fault hooks for a simulated node.
///
/// Three failure shapes, all reproducible from a seed:
///
/// * `crash()` / `revive()` — the node is down: every operation returns
///   [`Error::NodeDown`] until revived (kill-a-replica tests);
/// * `fail_next(n)` — exactly the next `n` operations fail with a
///   transient [`Error::Storage`] (targeted mid-write faults);
/// * `set_error_rate(p)` — each operation independently fails with
///   probability `p`, drawn from an RNG seeded at construction, so two
///   runs with the same seed and operation sequence fire the same faults;
/// * `set_delay_range(lo, hi)` — each operation sleeps a seeded-uniform
///   duration from `[lo, hi]` before touching the engine (degraded-node
///   and tail-latency scenarios: the op succeeds, just late).
///
/// Every fired transient fault records the operation sequence number at
/// which it fired ([`FaultInjector::fired`]); tests compare these logs
/// across runs to prove a scenario is reproducible from its seed.
pub struct FaultInjector {
    seed: u64,
    crashed: AtomicBool,
    fail_next: AtomicU64,
    rate: Mutex<Option<(f64, Rng)>>,
    delay: Mutex<Option<(u64, u64, Rng)>>,
    op_seq: AtomicU64,
    fired: Mutex<Vec<u64>>,
}

impl FaultInjector {
    pub fn new(seed: u64) -> Self {
        FaultInjector {
            seed,
            crashed: AtomicBool::new(false),
            fail_next: AtomicU64::new(0),
            rate: Mutex::new(None),
            delay: Mutex::new(None),
            op_seq: AtomicU64::new(0),
            fired: Mutex::new(Vec::new()),
        }
    }

    /// Kill the node: every subsequent operation fails with
    /// [`Error::NodeDown`] until [`FaultInjector::revive`].
    pub fn crash(&self) {
        self.crashed.store(true, Ordering::Release);
    }

    /// Bring a crashed node back. Its contents are whatever they were at
    /// the crash — catch-up is the replication layer's job.
    pub fn revive(&self) {
        self.crashed.store(false, Ordering::Release);
    }

    pub fn is_crashed(&self) -> bool {
        self.crashed.load(Ordering::Acquire)
    }

    /// Fail exactly the next `n` operations with a transient error.
    pub fn fail_next(&self, n: u64) {
        self.fail_next.store(n, Ordering::Release);
    }

    /// Fail each subsequent operation with probability `p`, drawn from
    /// the injector's seeded RNG. `0.0` disables the rate.
    pub fn set_error_rate(&self, p: f64) {
        let mut g = self.rate.lock().unwrap();
        *g = if p > 0.0 { Some((p, Rng::new(self.seed))) } else { None };
    }

    /// Delay each subsequent operation by a seeded-uniform duration in
    /// `[lo, hi]` — a slow node rather than a dead one. The draw
    /// sequence restarts from the injector's seed, so a run's delays
    /// are as reproducible as its faults. `Duration::ZERO, ZERO`
    /// disables the delay.
    pub fn set_delay_range(&self, lo: Duration, hi: Duration) {
        let (lo_us, hi_us) = (lo.as_micros() as u64, hi.as_micros() as u64);
        let mut g = self.delay.lock().unwrap();
        *g = if hi_us > 0 && hi_us >= lo_us {
            Some((lo_us, hi_us, Rng::new(self.seed)))
        } else {
            None
        };
    }

    /// Operation sequence numbers at which transient faults fired — the
    /// determinism probe: same seed + same op sequence = same log.
    pub fn fired(&self) -> Vec<u64> {
        self.fired.lock().unwrap().clone()
    }

    /// Total operations checked so far (crashed ops included).
    pub fn ops_checked(&self) -> u64 {
        self.op_seq.load(Ordering::Relaxed)
    }

    /// Gate one operation. Called by [`SimulatedStore`] before the inner
    /// engine is touched, so a fault never half-applies a batch.
    pub fn check(&self, op: &'static str) -> Result<()> {
        let seq = self.op_seq.fetch_add(1, Ordering::Relaxed);
        if self.crashed.load(Ordering::Acquire) {
            return Err(Error::NodeDown(format!("simulated node crash ({op})")));
        }
        let mut cur = self.fail_next.load(Ordering::Relaxed);
        while cur > 0 {
            match self.fail_next.compare_exchange_weak(
                cur,
                cur - 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.fired.lock().unwrap().push(seq);
                    return Err(Error::Storage(format!("injected transient fault ({op})")));
                }
                Err(actual) => cur = actual,
            }
        }
        let mut g = self.rate.lock().unwrap();
        if let Some((p, rng)) = g.as_mut() {
            if rng.chance(*p) {
                drop(g);
                self.fired.lock().unwrap().push(seq);
                return Err(Error::Storage(format!("injected transient fault ({op})")));
            }
        }
        drop(g);
        // Latency injection last: a delayed op still runs, so the sleep
        // happens only after every failure hook has passed.
        let sleep_us = {
            let mut d = self.delay.lock().unwrap();
            d.as_mut().map(|(lo, hi, rng)| *lo + rng.next_u64() % (*hi - *lo + 1))
        };
        if let Some(us) = sleep_us {
            precise_sleep(Duration::from_micros(us));
        }
        Ok(())
    }
}

/// An engine wrapper charging wall-clock time per the device profile.
pub struct SimulatedStore {
    inner: Engine,
    profile: DeviceProfile,
    time_scale: f64,
    sem: Semaphore,
    /// IOPS governor: earliest next-op time, in ns since `epoch`.
    next_slot_ns: AtomicU64,
    epoch: Instant,
    /// Total charged device time, ns (observability for benches).
    charged_ns: AtomicU64,
    faults: FaultInjector,
}

impl SimulatedStore {
    pub fn new(inner: Engine, profile: DeviceProfile, time_scale: f64) -> Self {
        Self::with_faults(inner, profile, time_scale, 0)
    }

    /// Like [`SimulatedStore::new`], with the fault injector's RNG seeded
    /// at `seed` (faults stay inert until armed via [`SimulatedStore::faults`]).
    pub fn with_faults(inner: Engine, profile: DeviceProfile, time_scale: f64, seed: u64) -> Self {
        SimulatedStore {
            sem: Semaphore::new(profile.parallelism.max(1)),
            inner,
            profile,
            time_scale,
            next_slot_ns: AtomicU64::new(0),
            epoch: Instant::now(),
            charged_ns: AtomicU64::new(0),
            faults: FaultInjector::new(seed),
        }
    }

    /// A zero-latency store with fault hooks: [`DeviceProfile::instant`]
    /// over `inner`. The failover test harness's standard node.
    pub fn instant(inner: Engine, seed: u64) -> Self {
        Self::with_faults(inner, DeviceProfile::instant(), 1.0, seed)
    }

    pub fn profile(&self) -> &DeviceProfile {
        &self.profile
    }

    /// The node's deterministic fault hooks.
    pub fn faults(&self) -> &FaultInjector {
        &self.faults
    }

    /// Total device-time charged so far, in (unscaled) microseconds.
    pub fn charged_us(&self) -> f64 {
        self.charged_ns.load(Ordering::Relaxed) as f64 / 1_000.0 / self.time_scale
    }

    /// Enforce the IOPS cap: reserve the next available op slot and wait
    /// until it arrives.
    fn govern_iops(&self) {
        if self.profile.iops <= 0.0 {
            return;
        }
        let spacing_ns = (1e9 / self.profile.iops * self.time_scale) as u64;
        let now_ns = self.epoch.elapsed().as_nanos() as u64;
        // Reserve a slot: max(now, next) then advance by spacing.
        let mut cur = self.next_slot_ns.load(Ordering::Relaxed);
        let slot = loop {
            let slot = cur.max(now_ns);
            match self.next_slot_ns.compare_exchange_weak(
                cur,
                slot + spacing_ns,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break slot,
                Err(actual) => cur = actual,
            }
        };
        let now_ns = self.epoch.elapsed().as_nanos() as u64;
        if slot > now_ns {
            precise_sleep(Duration::from_nanos(slot - now_ns));
        }
    }

    /// Charge `us` (already at device scale) of device time, holding a
    /// parallelism permit for its duration.
    fn charge(&self, us: f64) {
        let scaled = Duration::from_nanos((us * 1_000.0 * self.time_scale) as u64);
        self.charged_ns.fetch_add(scaled.as_nanos() as u64, Ordering::Relaxed);
        self.sem.acquire();
        precise_sleep(scaled);
        self.sem.release();
    }
}

/// Sleep with sub-millisecond fidelity: OS sleep for the bulk, spin the
/// tail (OS sleep granularity would otherwise flatten the SSD profile).
fn precise_sleep(d: Duration) {
    let start = Instant::now();
    if d > Duration::from_micros(300) {
        std::thread::sleep(d - Duration::from_micros(200));
    }
    while start.elapsed() < d {
        std::hint::spin_loop();
    }
}

impl StorageEngine for SimulatedStore {
    fn name(&self) -> &str {
        self.profile.name
    }

    fn get(&self, table: &str, key: u64) -> Result<Option<Blob>> {
        self.faults.check("get")?;
        let v = self.inner.get(table, key)?;
        self.govern_iops();
        let bytes = v.as_ref().map(|v| v.len() as u64).unwrap_or(512);
        self.charge(self.profile.read_cost_us(bytes));
        Ok(v)
    }

    fn put(&self, table: &str, key: u64, value: &[u8]) -> Result<()> {
        self.faults.check("put")?;
        self.govern_iops();
        self.charge(self.profile.write_cost_us(value.len() as u64));
        self.inner.put(table, key, value)
    }

    fn delete(&self, table: &str, key: u64) -> Result<()> {
        self.faults.check("delete")?;
        self.govern_iops();
        self.charge(self.profile.write_cost_us(512));
        self.inner.delete(table, key)
    }

    fn delete_batch(&self, table: &str, keys: &[u64]) -> Result<()> {
        if keys.is_empty() {
            return Ok(());
        }
        self.faults.check("delete_batch")?;
        // Like `put_batch`: one positioning cost plus streaming for the
        // batched tombstones (512 B of metadata per key).
        self.govern_iops();
        self.charge(self.profile.write_cost_us(512 * keys.len() as u64));
        self.inner.delete_batch(table, keys)
    }

    fn get_batch(&self, table: &str, keys: &[u64]) -> Result<Vec<Option<Blob>>> {
        // Batch of point reads: each pays its own seek (keys may be
        // scattered); use `get_run` for contiguous runs.
        self.faults.check("get_batch")?;
        let vs = self.inner.get_batch(table, keys)?;
        for v in &vs {
            self.govern_iops();
            let bytes = v.as_ref().map(|v| v.len() as u64).unwrap_or(512);
            self.charge(self.profile.read_cost_us(bytes));
        }
        Ok(vs)
    }

    fn put_batch(&self, table: &str, items: &[(u64, Vec<u8>)]) -> Result<()> {
        // One positioning cost + streaming for the whole batch: batching
        // amortizes fixed costs (§4.2 "Batch Interfaces").
        self.faults.check("put_batch")?;
        let total: u64 = items.iter().map(|(_, v)| v.len() as u64).sum();
        self.govern_iops();
        self.charge(self.profile.write_cost_us(total));
        self.inner.put_batch(table, items)
    }

    fn get_run(&self, table: &str, start: u64, len: u64) -> Result<Vec<(u64, Blob)>> {
        // THE Morton payoff: one seek + stream for the whole contiguous
        // run, regardless of how many cuboids it contains.
        self.faults.check("get_run")?;
        let vs = self.inner.get_run(table, start, len)?;
        let total: u64 = vs.iter().map(|(_, v)| v.len() as u64).sum();
        self.govern_iops();
        self.charge(self.profile.read_cost_us(total.max(512)));
        Ok(vs)
    }

    fn keys(&self, table: &str) -> Result<Vec<u64>> {
        self.faults.check("keys")?;
        self.inner.keys(table)
    }

    fn tables(&self) -> Result<Vec<String>> {
        self.faults.check("tables")?;
        self.inner.tables()
    }

    fn stats(&self) -> &IoStats {
        self.inner.stats()
    }

    fn sync(&self) -> Result<()> {
        self.faults.check("sync")?;
        self.inner.sync()
    }

    fn fault_injector(&self) -> Option<&FaultInjector> {
        Some(&self.faults)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;
    use std::sync::Arc;

    fn sim(profile: DeviceProfile, scale: f64) -> SimulatedStore {
        SimulatedStore::new(Arc::new(MemStore::new()), profile, scale)
    }

    #[test]
    fn conformance() {
        // Tiny time scale so the suite stays fast.
        let s = sim(DeviceProfile::ssd_raid0(), 0.001);
        crate::storage::tests::conformance(&s);
    }

    #[test]
    fn run_read_cheaper_than_point_reads() {
        let s = sim(DeviceProfile::hdd_array(), 0.01);
        let items: Vec<(u64, Vec<u8>)> = (0..64).map(|k| (k, vec![0u8; 4096])).collect();
        s.put_batch("t", &items).unwrap();
        let keys: Vec<u64> = (0..64).collect();

        let t0 = Instant::now();
        let _ = s.get_batch("t", &keys).unwrap();
        let scattered = t0.elapsed();

        let t0 = Instant::now();
        let run = s.get_run("t", 0, 64).unwrap();
        let sequential = t0.elapsed();

        assert_eq!(run.len(), 64);
        assert!(
            scattered > sequential * 10,
            "expected >=10x: scattered={scattered:?} sequential={sequential:?}"
        );
    }

    #[test]
    fn ssd_beats_hdd_on_small_random_writes() {
        // Figure 13's mechanism.
        let hdd = sim(DeviceProfile::hdd_array(), 0.01);
        let ssd = sim(DeviceProfile::ssd_raid0(), 0.01);
        let time_writes = |s: &SimulatedStore| {
            let t0 = Instant::now();
            for k in 0..50u64 {
                s.put("t", k * 7919, &[0u8; 256]).unwrap();
            }
            t0.elapsed()
        };
        let h = time_writes(&hdd);
        let s = time_writes(&ssd);
        assert!(h > s * 3, "hdd={h:?} ssd={s:?}");
    }

    #[test]
    fn iops_cap_limits_rate() {
        let prof = DeviceProfile { iops: 10_000.0, ..DeviceProfile::ssd_raid0() };
        let s = sim(prof, 1.0); // real time, tiny op count
        for k in 0..40u64 {
            s.put("t", k, &[0u8; 16]).unwrap();
        }
        let t0 = Instant::now();
        for k in 0..40u64 {
            let _ = s.get("t", k).unwrap();
        }
        let dt = t0.elapsed();
        // 40 ops at 10K IOPS needs >= ~4ms.
        assert!(dt >= Duration::from_micros(3_500), "iops cap not enforced: {dt:?}");
    }

    #[test]
    fn charged_time_accounts_scale() {
        let s = sim(DeviceProfile::hdd_array(), 0.001);
        s.put("t", 0, &[0u8; 1024]).unwrap();
        let us = s.charged_us();
        // One random write: ~16ms seek-equivalent at device scale.
        assert!(us > 10_000.0 && us < 30_000.0, "charged {us}");
    }

    fn instant(seed: u64) -> SimulatedStore {
        SimulatedStore::instant(Arc::new(MemStore::new()), seed)
    }

    #[test]
    fn crash_downs_every_op_until_revive() {
        let s = instant(1);
        s.put("t", 1, b"v").unwrap();
        s.faults().crash();
        assert!(s.faults().is_crashed());
        assert!(matches!(s.get("t", 1), Err(Error::NodeDown(_))));
        assert!(matches!(s.put("t", 2, b"w"), Err(Error::NodeDown(_))));
        assert!(matches!(s.keys("t"), Err(Error::NodeDown(_))));
        assert!(matches!(s.sync(), Err(Error::NodeDown(_))));
        s.faults().revive();
        // Contents from before the crash survive; the failed put is absent.
        assert_eq!(s.get("t", 1).unwrap().as_deref().map(|v| &v[..]), Some(&b"v"[..]));
        assert!(s.get("t", 2).unwrap().is_none());
    }

    #[test]
    fn fail_next_fails_exactly_n_ops() {
        let s = instant(2);
        s.faults().fail_next(2);
        assert!(matches!(s.put("t", 0, b"a"), Err(Error::Storage(_))));
        assert!(matches!(s.get("t", 0), Err(Error::Storage(_))));
        // Third op sails through, and the failed put never half-applied.
        assert!(s.get("t", 0).unwrap().is_none());
        s.put("t", 0, b"a").unwrap();
        assert!(s.get("t", 0).unwrap().is_some());
        assert_eq!(s.faults().fired(), vec![0, 1]);
    }

    #[test]
    fn error_rate_is_deterministic_from_seed() {
        let run = |seed: u64| {
            let s = instant(seed);
            s.faults().set_error_rate(0.3);
            let mut outcomes = Vec::new();
            for k in 0..200u64 {
                outcomes.push(s.put("t", k, b"x").is_ok());
            }
            (outcomes, s.faults().fired())
        };
        let (a, fa) = run(42);
        let (b, fb) = run(42);
        assert_eq!(a, b, "same seed must fail the same ops");
        assert_eq!(fa, fb);
        assert!(!fa.is_empty(), "0.3 over 200 ops should fire");
        let (c, _) = run(43);
        assert_ne!(a, c, "different seed should fault differently");
        // Disarming stops the faults.
        let s = instant(42);
        s.faults().set_error_rate(0.9);
        s.faults().set_error_rate(0.0);
        for k in 0..50u64 {
            s.put("t", k, b"x").unwrap();
        }
    }

    #[test]
    fn delay_range_slows_ops_and_disarms_clean() {
        let s = instant(9);
        s.faults().set_delay_range(Duration::from_micros(500), Duration::from_micros(800));
        let t0 = Instant::now();
        for k in 0..5u64 {
            s.put("t", k, b"x").unwrap();
        }
        // Five ops, each ≥ 500µs of injected latency.
        assert!(
            t0.elapsed() >= Duration::from_micros(2_500),
            "delays not applied: {:?}",
            t0.elapsed()
        );
        // Zero range disarms; ops still succeed (and the data landed).
        s.faults().set_delay_range(Duration::ZERO, Duration::ZERO);
        for k in 0..5u64 {
            assert!(s.get("t", k).unwrap().is_some());
        }
    }

    #[test]
    fn delay_rng_does_not_perturb_fault_draws() {
        // The delay hook carries its own seeded RNG: arming it must not
        // shift which ops the error rate fails, or a latency experiment
        // would silently change the fault schedule it runs under.
        let run = |with_delay: bool| {
            let f = FaultInjector::new(5);
            f.set_error_rate(0.3);
            if with_delay {
                f.set_delay_range(Duration::from_micros(1), Duration::from_micros(2));
            }
            for _ in 0..100 {
                let _ = f.check("op");
            }
            f.fired()
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn fault_injector_reachable_through_engine_trait() {
        let s: Engine = Arc::new(instant(7));
        s.fault_injector().unwrap().crash();
        assert!(matches!(s.get("t", 0), Err(Error::NodeDown(_))));
        let m: Engine = Arc::new(MemStore::new());
        assert!(m.fault_injector().is_none());
    }
}
