//! Simulated storage devices: an I/O cost model layered over any engine.
//!
//! The paper's Figures 10–13 are shaped by device economics we cannot
//! reproduce literally (a Dell H700 RAID-6 array of 11 SATA drives vs. a
//! RAID-0 pair of OCZ Vertex4 SSDs). [`SimulatedStore`] wraps a content
//! engine (usually [`super::MemStore`]) and charges each operation wall
//! clock according to a [`DeviceProfile`]:
//!
//! * every random operation pays a positioning latency (`seek`);
//! * transfers pay `bytes / bandwidth`;
//! * a contiguous run read pays ONE seek plus streaming for the whole run
//!   — this is precisely the benefit the Morton layout buys (§5);
//! * ops-per-second is capped (`iops`) — the paper's SSD nodes "realize
//!   20K IOPS of the theoretical 120K" (§4.1);
//! * at most `parallelism` operations progress concurrently (spindle /
//!   channel count) — excess callers queue, which produces the saturation
//!   and decline of Figure 11.
//!
//! `time_scale` shrinks all charged latencies by a constant factor so the
//! benches finish quickly; every reported throughput is scaled back up by
//! the caller (the *ratios* between configurations are scale-invariant).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::storage::{Blob, Engine, IoStats, StorageEngine};
use crate::Result;

/// Cost model for one device class.
#[derive(Clone, Copy, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Positioning latency per random read, microseconds.
    pub read_seek_us: f64,
    /// Positioning latency per random write, microseconds. RAID-6 pays a
    /// parity read-modify-write penalty on small writes, so this exceeds
    /// `read_seek_us` on the disk-array profile.
    pub write_seek_us: f64,
    /// Streaming read bandwidth, MB/s.
    pub read_mbps: f64,
    /// Streaming write bandwidth, MB/s.
    pub write_mbps: f64,
    /// Max random operations per second (0 = uncapped / seek-bound).
    pub iops: f64,
    /// Concurrent operations the device sustains before queueing.
    pub parallelism: usize,
}

impl DeviceProfile {
    /// The paper's Database-node storage: Dell H700 RAID-6 over 11 SATA
    /// drives (§4.1/§5). Good streaming, seek-bound random reads, and a
    /// painful small-write penalty from RAID-6 parity.
    pub fn hdd_array() -> Self {
        DeviceProfile {
            name: "raid6-sata",
            read_seek_us: 8_000.0,
            write_seek_us: 16_000.0, // parity read-modify-write
            read_mbps: 350.0,
            write_mbps: 250.0,
            iops: 0.0,
            parallelism: 10, // spindles minus parity overhead
        }
    }

    /// The paper's SSD-node storage: two OCZ Vertex4 in RAID-0, realizing
    /// 20K IOPS behind a weak controller (§4.1).
    pub fn ssd_raid0() -> Self {
        DeviceProfile {
            name: "ssd-vertex4",
            read_seek_us: 120.0,
            write_seek_us: 150.0,
            read_mbps: 450.0,
            write_mbps: 380.0,
            iops: 20_000.0,
            parallelism: 16,
        }
    }

    /// Cost in microseconds of a random read of `bytes`.
    fn read_cost_us(&self, bytes: u64) -> f64 {
        self.read_seek_us + bytes as f64 / self.read_mbps
    }

    /// Cost in microseconds of a random write of `bytes`.
    fn write_cost_us(&self, bytes: u64) -> f64 {
        self.write_seek_us + bytes as f64 / self.write_mbps
    }
    // (1 byte / (MB/s)) == 1 µs/MB == bytes/mbps µs — the units line up
    // because 1 MB/s moves one byte per microsecond.
}

/// Counting semaphore (no external deps available offline).
struct Semaphore {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    fn new(n: usize) -> Self {
        Semaphore { permits: Mutex::new(n), cv: Condvar::new() }
    }

    fn acquire(&self) {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
    }

    fn release(&self) {
        *self.permits.lock().unwrap() += 1;
        self.cv.notify_one();
    }
}

/// An engine wrapper charging wall-clock time per the device profile.
pub struct SimulatedStore {
    inner: Engine,
    profile: DeviceProfile,
    time_scale: f64,
    sem: Semaphore,
    /// IOPS governor: earliest next-op time, in ns since `epoch`.
    next_slot_ns: AtomicU64,
    epoch: Instant,
    /// Total charged device time, ns (observability for benches).
    charged_ns: AtomicU64,
}

impl SimulatedStore {
    pub fn new(inner: Engine, profile: DeviceProfile, time_scale: f64) -> Self {
        SimulatedStore {
            sem: Semaphore::new(profile.parallelism.max(1)),
            inner,
            profile,
            time_scale,
            next_slot_ns: AtomicU64::new(0),
            epoch: Instant::now(),
            charged_ns: AtomicU64::new(0),
        }
    }

    pub fn profile(&self) -> &DeviceProfile {
        &self.profile
    }

    /// Total device-time charged so far, in (unscaled) microseconds.
    pub fn charged_us(&self) -> f64 {
        self.charged_ns.load(Ordering::Relaxed) as f64 / 1_000.0 / self.time_scale
    }

    /// Enforce the IOPS cap: reserve the next available op slot and wait
    /// until it arrives.
    fn govern_iops(&self) {
        if self.profile.iops <= 0.0 {
            return;
        }
        let spacing_ns = (1e9 / self.profile.iops * self.time_scale) as u64;
        let now_ns = self.epoch.elapsed().as_nanos() as u64;
        // Reserve a slot: max(now, next) then advance by spacing.
        let mut cur = self.next_slot_ns.load(Ordering::Relaxed);
        let slot = loop {
            let slot = cur.max(now_ns);
            match self.next_slot_ns.compare_exchange_weak(
                cur,
                slot + spacing_ns,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break slot,
                Err(actual) => cur = actual,
            }
        };
        let now_ns = self.epoch.elapsed().as_nanos() as u64;
        if slot > now_ns {
            precise_sleep(Duration::from_nanos(slot - now_ns));
        }
    }

    /// Charge `us` (already at device scale) of device time, holding a
    /// parallelism permit for its duration.
    fn charge(&self, us: f64) {
        let scaled = Duration::from_nanos((us * 1_000.0 * self.time_scale) as u64);
        self.charged_ns.fetch_add(scaled.as_nanos() as u64, Ordering::Relaxed);
        self.sem.acquire();
        precise_sleep(scaled);
        self.sem.release();
    }
}

/// Sleep with sub-millisecond fidelity: OS sleep for the bulk, spin the
/// tail (OS sleep granularity would otherwise flatten the SSD profile).
fn precise_sleep(d: Duration) {
    let start = Instant::now();
    if d > Duration::from_micros(300) {
        std::thread::sleep(d - Duration::from_micros(200));
    }
    while start.elapsed() < d {
        std::hint::spin_loop();
    }
}

impl StorageEngine for SimulatedStore {
    fn name(&self) -> &str {
        self.profile.name
    }

    fn get(&self, table: &str, key: u64) -> Result<Option<Blob>> {
        let v = self.inner.get(table, key)?;
        self.govern_iops();
        let bytes = v.as_ref().map(|v| v.len() as u64).unwrap_or(512);
        self.charge(self.profile.read_cost_us(bytes));
        Ok(v)
    }

    fn put(&self, table: &str, key: u64, value: &[u8]) -> Result<()> {
        self.govern_iops();
        self.charge(self.profile.write_cost_us(value.len() as u64));
        self.inner.put(table, key, value)
    }

    fn delete(&self, table: &str, key: u64) -> Result<()> {
        self.govern_iops();
        self.charge(self.profile.write_cost_us(512));
        self.inner.delete(table, key)
    }

    fn delete_batch(&self, table: &str, keys: &[u64]) -> Result<()> {
        if keys.is_empty() {
            return Ok(());
        }
        // Like `put_batch`: one positioning cost plus streaming for the
        // batched tombstones (512 B of metadata per key).
        self.govern_iops();
        self.charge(self.profile.write_cost_us(512 * keys.len() as u64));
        self.inner.delete_batch(table, keys)
    }

    fn get_batch(&self, table: &str, keys: &[u64]) -> Result<Vec<Option<Blob>>> {
        // Batch of point reads: each pays its own seek (keys may be
        // scattered); use `get_run` for contiguous runs.
        let vs = self.inner.get_batch(table, keys)?;
        for v in &vs {
            self.govern_iops();
            let bytes = v.as_ref().map(|v| v.len() as u64).unwrap_or(512);
            self.charge(self.profile.read_cost_us(bytes));
        }
        Ok(vs)
    }

    fn put_batch(&self, table: &str, items: &[(u64, Vec<u8>)]) -> Result<()> {
        // One positioning cost + streaming for the whole batch: batching
        // amortizes fixed costs (§4.2 "Batch Interfaces").
        let total: u64 = items.iter().map(|(_, v)| v.len() as u64).sum();
        self.govern_iops();
        self.charge(self.profile.write_cost_us(total));
        self.inner.put_batch(table, items)
    }

    fn get_run(&self, table: &str, start: u64, len: u64) -> Result<Vec<(u64, Blob)>> {
        // THE Morton payoff: one seek + stream for the whole contiguous
        // run, regardless of how many cuboids it contains.
        let vs = self.inner.get_run(table, start, len)?;
        let total: u64 = vs.iter().map(|(_, v)| v.len() as u64).sum();
        self.govern_iops();
        self.charge(self.profile.read_cost_us(total.max(512)));
        Ok(vs)
    }

    fn keys(&self, table: &str) -> Result<Vec<u64>> {
        self.inner.keys(table)
    }

    fn tables(&self) -> Result<Vec<String>> {
        self.inner.tables()
    }

    fn stats(&self) -> &IoStats {
        self.inner.stats()
    }

    fn sync(&self) -> Result<()> {
        self.inner.sync()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;
    use std::sync::Arc;

    fn sim(profile: DeviceProfile, scale: f64) -> SimulatedStore {
        SimulatedStore::new(Arc::new(MemStore::new()), profile, scale)
    }

    #[test]
    fn conformance() {
        // Tiny time scale so the suite stays fast.
        let s = sim(DeviceProfile::ssd_raid0(), 0.001);
        crate::storage::tests::conformance(&s);
    }

    #[test]
    fn run_read_cheaper_than_point_reads() {
        let s = sim(DeviceProfile::hdd_array(), 0.01);
        let items: Vec<(u64, Vec<u8>)> = (0..64).map(|k| (k, vec![0u8; 4096])).collect();
        s.put_batch("t", &items).unwrap();
        let keys: Vec<u64> = (0..64).collect();

        let t0 = Instant::now();
        let _ = s.get_batch("t", &keys).unwrap();
        let scattered = t0.elapsed();

        let t0 = Instant::now();
        let run = s.get_run("t", 0, 64).unwrap();
        let sequential = t0.elapsed();

        assert_eq!(run.len(), 64);
        assert!(
            scattered > sequential * 10,
            "expected >=10x: scattered={scattered:?} sequential={sequential:?}"
        );
    }

    #[test]
    fn ssd_beats_hdd_on_small_random_writes() {
        // Figure 13's mechanism.
        let hdd = sim(DeviceProfile::hdd_array(), 0.01);
        let ssd = sim(DeviceProfile::ssd_raid0(), 0.01);
        let time_writes = |s: &SimulatedStore| {
            let t0 = Instant::now();
            for k in 0..50u64 {
                s.put("t", k * 7919, &[0u8; 256]).unwrap();
            }
            t0.elapsed()
        };
        let h = time_writes(&hdd);
        let s = time_writes(&ssd);
        assert!(h > s * 3, "hdd={h:?} ssd={s:?}");
    }

    #[test]
    fn iops_cap_limits_rate() {
        let prof = DeviceProfile { iops: 10_000.0, ..DeviceProfile::ssd_raid0() };
        let s = sim(prof, 1.0); // real time, tiny op count
        for k in 0..40u64 {
            s.put("t", k, &[0u8; 16]).unwrap();
        }
        let t0 = Instant::now();
        for k in 0..40u64 {
            let _ = s.get("t", k).unwrap();
        }
        let dt = t0.elapsed();
        // 40 ops at 10K IOPS needs >= ~4ms.
        assert!(dt >= Duration::from_micros(3_500), "iops cap not enforced: {dt:?}");
    }

    #[test]
    fn charged_time_accounts_scale() {
        let s = sim(DeviceProfile::hdd_array(), 0.001);
        s.put("t", 0, &[0u8; 1024]).unwrap();
        let us = s.charged_us();
        // One random write: ~16ms seek-equivalent at device scale.
        assert!(us > 10_000.0 && us < 30_000.0, "charged {us}");
    }
}
