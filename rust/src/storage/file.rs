//! File-backed storage engine: one append-only data log + index log per
//! table, with an in-memory page table (key → offset, len).
//!
//! This mirrors the paper's append-mostly physical design (§4.2: "The
//! workload suits an append-mostly physical design"): puts append to the
//! data log and the index log; gets are positioned reads; contiguous
//! Morton runs over keys written in Morton order become sequential file
//! reads. Replaced values leave garbage in the log; `compact` rewrites a
//! table (the dump/restore analogue used after bulk rewrites).

use std::collections::{BTreeMap, HashMap};
use std::fs::{self, File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::{Mutex, RwLock};

use crate::storage::{Blob, IoStats, StorageEngine};
use crate::{Error, Result};

const IDX_RECORD: usize = 8 + 8 + 8; // key, offset, len (tombstone: len = u64::MAX)

struct TableFiles {
    data: Mutex<File>,
    index: Mutex<File>,
    /// key -> (offset, len) in the data log.
    pages: RwLock<BTreeMap<u64, (u64, u64)>>,
}

/// Append-log file engine rooted at a directory.
pub struct FileStore {
    root: PathBuf,
    tables: RwLock<HashMap<String, &'static TableFiles>>,
    stats: IoStats,
}

impl FileStore {
    /// Open (or create) a store rooted at `root`, replaying any existing
    /// index logs.
    pub fn open(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        fs::create_dir_all(&root)?;
        let store = FileStore { root, tables: RwLock::new(HashMap::new()), stats: IoStats::default() };
        // Discover existing tables (directory tree of <table>.data files;
        // table names may contain '/' which we encode as '\x01' on disk).
        for entry in fs::read_dir(&store.root)? {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().to_string();
            if let Some(stem) = name.strip_suffix(".data") {
                let table = stem.replace('\x01', "/");
                store.table(&table)?;
            }
        }
        Ok(store)
    }

    fn path_for(&self, table: &str, ext: &str) -> PathBuf {
        self.root.join(format!("{}.{ext}", table.replace('/', "\x01")))
    }

    /// Get or open the table file pair. Table handles are leaked
    /// intentionally: a store has a small, stable set of tables and the
    /// handles must be shareable across threads without lifetimes.
    fn table(&self, name: &str) -> Result<&'static TableFiles> {
        if let Some(t) = self.tables.read().unwrap().get(name) {
            return Ok(t);
        }
        let mut tables = self.tables.write().unwrap();
        if let Some(t) = tables.get(name) {
            return Ok(t);
        }
        let data_path = self.path_for(name, "data");
        let idx_path = self.path_for(name, "idx");
        let data = OpenOptions::new().create(true).read(true).append(true).open(&data_path)?;
        let mut index =
            OpenOptions::new().create(true).read(true).append(true).open(&idx_path)?;
        // Replay the index log.
        let mut pages = BTreeMap::new();
        let mut buf = Vec::new();
        index.seek(SeekFrom::Start(0))?;
        index.read_to_end(&mut buf)?;
        if buf.len() % IDX_RECORD != 0 {
            return Err(Error::Storage(format!(
                "corrupt index {idx_path:?}: {} bytes",
                buf.len()
            )));
        }
        for rec in buf.chunks_exact(IDX_RECORD) {
            let key = u64::from_le_bytes(rec[0..8].try_into().unwrap());
            let off = u64::from_le_bytes(rec[8..16].try_into().unwrap());
            let len = u64::from_le_bytes(rec[16..24].try_into().unwrap());
            if len == u64::MAX {
                pages.remove(&key);
            } else {
                pages.insert(key, (off, len));
            }
        }
        let files: &'static TableFiles = Box::leak(Box::new(TableFiles {
            data: Mutex::new(data),
            index: Mutex::new(index),
            pages: RwLock::new(pages),
        }));
        tables.insert(name.to_string(), files);
        Ok(files)
    }

    fn read_at(&self, table: &TableFiles, off: u64, len: u64) -> Result<Vec<u8>> {
        let mut buf = vec![0u8; len as usize];
        let mut f = table.data.lock().unwrap();
        f.seek(SeekFrom::Start(off))?;
        f.read_exact(&mut buf)?;
        Ok(buf)
    }

    fn append_index(&self, table: &TableFiles, key: u64, off: u64, len: u64) -> Result<()> {
        let mut rec = [0u8; IDX_RECORD];
        rec[0..8].copy_from_slice(&key.to_le_bytes());
        rec[8..16].copy_from_slice(&off.to_le_bytes());
        rec[16..24].copy_from_slice(&len.to_le_bytes());
        table.index.lock().unwrap().write_all(&rec)?;
        Ok(())
    }

    /// Rewrite a table's logs in key order, dropping garbage. Returns
    /// bytes reclaimed.
    pub fn compact(&self, name: &str) -> Result<u64> {
        let table = self.table(name)?;
        let entries: Vec<(u64, (u64, u64))> = {
            let pages = table.pages.read().unwrap();
            pages.iter().map(|(k, v)| (*k, *v)).collect()
        };
        let tmp_data = self.path_for(name, "data.tmp");
        let tmp_idx = self.path_for(name, "idx.tmp");
        let mut new_data = File::create(&tmp_data)?;
        let mut new_idx = File::create(&tmp_idx)?;
        let mut new_pages = BTreeMap::new();
        let mut off = 0u64;
        for (key, (old_off, len)) in entries {
            let v = self.read_at(table, old_off, len)?;
            new_data.write_all(&v)?;
            let mut rec = [0u8; IDX_RECORD];
            rec[0..8].copy_from_slice(&key.to_le_bytes());
            rec[8..16].copy_from_slice(&off.to_le_bytes());
            rec[16..24].copy_from_slice(&len.to_le_bytes());
            new_idx.write_all(&rec)?;
            new_pages.insert(key, (off, len));
            off += len;
        }
        new_data.sync_all()?;
        new_idx.sync_all()?;
        let old_size = fs::metadata(self.path_for(name, "data"))?.len();
        {
            // Swap under both locks.
            let mut d = table.data.lock().unwrap();
            let mut i = table.index.lock().unwrap();
            let mut p = table.pages.write().unwrap();
            fs::rename(&tmp_data, self.path_for(name, "data"))?;
            fs::rename(&tmp_idx, self.path_for(name, "idx"))?;
            *d = OpenOptions::new().read(true).append(true).open(self.path_for(name, "data"))?;
            *i = OpenOptions::new().read(true).append(true).open(self.path_for(name, "idx"))?;
            *p = new_pages;
        }
        Ok(old_size.saturating_sub(off))
    }
}

impl StorageEngine for FileStore {
    fn name(&self) -> &str {
        "file"
    }

    fn get(&self, table: &str, key: u64) -> Result<Option<Blob>> {
        let t = self.table(table)?;
        let loc = { t.pages.read().unwrap().get(&key).copied() };
        match loc {
            Some((off, len)) => {
                self.stats.record_read(len as usize);
                Ok(Some(std::sync::Arc::new(self.read_at(t, off, len)?)))
            }
            None => {
                self.stats.record_miss();
                Ok(None)
            }
        }
    }

    fn put(&self, table: &str, key: u64, value: &[u8]) -> Result<()> {
        let t = self.table(table)?;
        self.stats.record_write(value.len());
        let off = {
            let mut f = t.data.lock().unwrap();
            let off = f.seek(SeekFrom::End(0))?;
            f.write_all(value)?;
            off
        };
        self.append_index(t, key, off, value.len() as u64)?;
        t.pages.write().unwrap().insert(key, (off, value.len() as u64));
        Ok(())
    }

    fn delete(&self, table: &str, key: u64) -> Result<()> {
        let t = self.table(table)?;
        if t.pages.write().unwrap().remove(&key).is_some() {
            self.append_index(t, key, 0, u64::MAX)?;
        }
        Ok(())
    }

    fn delete_batch(&self, table: &str, keys: &[u64]) -> Result<()> {
        let t = self.table(table)?;
        // One page-table pass, then one index-log append holding every
        // tombstone (the batch analogue of `put_batch`'s single write).
        let present: Vec<u64> = {
            let mut pages = t.pages.write().unwrap();
            keys.iter().copied().filter(|k| pages.remove(k).is_some()).collect()
        };
        if present.is_empty() {
            return Ok(());
        }
        let mut idx_blob = Vec::with_capacity(present.len() * IDX_RECORD);
        for k in present {
            idx_blob.extend_from_slice(&k.to_le_bytes());
            idx_blob.extend_from_slice(&0u64.to_le_bytes());
            idx_blob.extend_from_slice(&u64::MAX.to_le_bytes());
        }
        t.index.lock().unwrap().write_all(&idx_blob)?;
        Ok(())
    }

    fn put_batch(&self, table: &str, items: &[(u64, Vec<u8>)]) -> Result<()> {
        let t = self.table(table)?;
        // One data-log append for the whole batch.
        let mut blob = Vec::with_capacity(items.iter().map(|(_, v)| v.len()).sum());
        let mut locs = Vec::with_capacity(items.len());
        for (k, v) in items {
            locs.push((*k, blob.len() as u64, v.len() as u64));
            blob.extend_from_slice(v);
            self.stats.record_write(v.len());
        }
        let base = {
            let mut f = t.data.lock().unwrap();
            let off = f.seek(SeekFrom::End(0))?;
            f.write_all(&blob)?;
            off
        };
        let mut idx_blob = Vec::with_capacity(items.len() * IDX_RECORD);
        for (k, rel, len) in &locs {
            idx_blob.extend_from_slice(&k.to_le_bytes());
            idx_blob.extend_from_slice(&(base + rel).to_le_bytes());
            idx_blob.extend_from_slice(&len.to_le_bytes());
        }
        t.index.lock().unwrap().write_all(&idx_blob)?;
        let mut pages = t.pages.write().unwrap();
        for (k, rel, len) in locs {
            pages.insert(k, (base + rel, len));
        }
        Ok(())
    }

    fn get_run(&self, table: &str, start: u64, len: u64) -> Result<Vec<(u64, Blob)>> {
        self.stats.record_run_read();
        let t = self.table(table)?;
        let end = start.saturating_add(len);
        let locs: Vec<(u64, (u64, u64))> = {
            let pages = t.pages.read().unwrap();
            pages.range(start..end).map(|(k, v)| (*k, *v)).collect()
        };
        // If the run is physically contiguous (the common case for data
        // ingested in Morton order), serve it as ONE streaming read.
        let ascending =
            locs.windows(2).all(|w| w[0].1 .0 + w[0].1 .1 <= w[1].1 .0);
        if let (true, Some(first), Some(last)) = (ascending, locs.first(), locs.last()) {
            let span = last.1 .0 + last.1 .1 - first.1 .0;
            let total: u64 = locs.iter().map(|(_, (_, l))| *l).sum();
            if span == total {
                let blob = self.read_at(t, first.1 .0, span)?;
                self.stats.record_read(span as usize);
                let mut out = Vec::with_capacity(locs.len());
                let base = first.1 .0;
                for (k, (off, l)) in locs {
                    let rel = (off - base) as usize;
                    out.push((k, std::sync::Arc::new(blob[rel..rel + l as usize].to_vec())));
                }
                return Ok(out);
            }
        }
        locs.into_iter()
            .map(|(k, (off, l))| {
                self.stats.record_read(l as usize);
                Ok((k, std::sync::Arc::new(self.read_at(t, off, l)?)))
            })
            .collect()
    }

    fn keys(&self, table: &str) -> Result<Vec<u64>> {
        let t = self.table(table)?;
        let pages = t.pages.read().unwrap();
        Ok(pages.keys().copied().collect())
    }

    fn tables(&self) -> Result<Vec<String>> {
        let mut names: Vec<String> = self.tables.read().unwrap().keys().cloned().collect();
        names.sort();
        Ok(names)
    }

    fn stats(&self) -> &IoStats {
        &self.stats
    }

    fn sync(&self) -> Result<()> {
        for t in self.tables.read().unwrap().values() {
            t.data.lock().unwrap().sync_all()?;
            t.index.lock().unwrap().sync_all()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ocpd-filestore-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn conformance() {
        let dir = tmpdir("conf");
        let fs_ = FileStore::open(&dir).unwrap();
        crate::storage::tests::conformance(&fs_);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn persistence_across_reopen() {
        let dir = tmpdir("persist");
        {
            let s = FileStore::open(&dir).unwrap();
            s.put("proj/cub/r0/c0", 42, b"hello").unwrap();
            s.put("proj/cub/r0/c0", 43, b"world").unwrap();
            s.delete("proj/cub/r0/c0", 42).unwrap();
            s.sync().unwrap();
        }
        {
            let s = FileStore::open(&dir).unwrap();
            assert_eq!(s.get("proj/cub/r0/c0", 42).unwrap(), None);
            assert_eq!(**s.get("proj/cub/r0/c0", 43).unwrap().unwrap(), *b"world");
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn morton_order_batch_is_one_sequential_run() {
        let dir = tmpdir("seq");
        let s = FileStore::open(&dir).unwrap();
        let items: Vec<(u64, Vec<u8>)> = (100..132).map(|k| (k, vec![k as u8; 64])).collect();
        s.put_batch("t", &items).unwrap();
        let before = s.stats().snapshot();
        let run = s.get_run("t", 100, 32).unwrap();
        assert_eq!(run.len(), 32);
        let after = s.stats().snapshot();
        // One streaming read, not 32 random reads.
        assert_eq!(after.reads - before.reads, 1, "run read should be one streaming I/O");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_reclaims_garbage() {
        let dir = tmpdir("compact");
        let s = FileStore::open(&dir).unwrap();
        for _ in 0..10 {
            s.put("t", 1, &[7u8; 1000]).unwrap(); // 9 dead versions
        }
        let reclaimed = s.compact("t").unwrap();
        assert_eq!(reclaimed, 9_000);
        assert_eq!(*s.get("t", 1).unwrap().unwrap(), vec![7u8; 1000]);
        let _ = fs::remove_dir_all(&dir);
    }
}
