//! Storage engines: keyed BLOB tables with batch and contiguous-run reads.
//!
//! The paper stores cuboids as BLOBs in MySQL tables laid out in Morton
//! order on RAID-6 disk arrays, with a separate class of SSD nodes for
//! random-write workloads (§4.1). Here a [`StorageEngine`] abstracts the
//! same access pattern:
//!
//! * [`MemStore`] — in-memory B-tree tables (the "in cache / aligned
//!   memory" configuration of Figure 10).
//! * [`FileStore`] — append-log + page-table persistence on the local
//!   filesystem.
//! * [`sim::SimulatedStore`] — wraps another engine with a device cost
//!   model (HDD array vs. SSD) so the benches reproduce the *shape* of the
//!   paper's I/O results without the paper's hardware (DESIGN.md §1).
//!
//! Keys are `u64` (Morton codes or object ids); tables are named by the
//! project helpers in [`crate::core::Project`].

mod file;
mod mem;
pub mod sim;

pub use file::FileStore;
pub use mem::MemStore;
pub use sim::{DeviceProfile, FaultInjector, SimulatedStore};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::Result;

/// Shared value blob: engines return `Arc`-shared bytes so the cutout hot
/// path never copies under (or after) the engine lock — the memory
/// configuration previously copied every cuboid once in the engine and
/// once in assembly.
pub type Blob = std::sync::Arc<Vec<u8>>;

/// Cumulative I/O statistics for an engine (feeds the benches and the
/// `ocpd info` CLI).
#[derive(Debug, Default)]
pub struct IoStats {
    pub reads: AtomicU64,
    pub read_bytes: AtomicU64,
    pub writes: AtomicU64,
    pub write_bytes: AtomicU64,
    pub run_reads: AtomicU64,
    pub misses: AtomicU64,
}

impl IoStats {
    pub fn record_read(&self, bytes: usize) {
        self.reads.fetch_add(1, Ordering::Relaxed);
        self.read_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn record_write(&self, bytes: usize) {
        self.writes.fetch_add(1, Ordering::Relaxed);
        self.write_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn record_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_run_read(&self) {
        self.run_reads.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> IoSnapshot {
        IoSnapshot {
            reads: self.reads.load(Ordering::Relaxed),
            read_bytes: self.read_bytes.load(Ordering::Relaxed),
            writes: self.writes.load(Ordering::Relaxed),
            write_bytes: self.write_bytes.load(Ordering::Relaxed),
            run_reads: self.run_reads.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`IoStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IoSnapshot {
    pub reads: u64,
    pub read_bytes: u64,
    pub writes: u64,
    pub write_bytes: u64,
    pub run_reads: u64,
    pub misses: u64,
}

/// A keyed BLOB store with batch and contiguous-run access.
///
/// `get_run` is the Morton payoff: a contiguous key run maps to physically
/// sequential storage, so engines can serve it as one streaming read
/// instead of `len` random reads.
pub trait StorageEngine: Send + Sync {
    /// Engine name for logs/benches.
    fn name(&self) -> &str;

    /// Read one value.
    fn get(&self, table: &str, key: u64) -> Result<Option<Blob>>;

    /// Write one value (create or replace).
    fn put(&self, table: &str, key: u64, value: &[u8]) -> Result<()>;

    /// Delete one value; no-op if absent.
    fn delete(&self, table: &str, key: u64) -> Result<()>;

    /// Delete many keys in one transaction-like batch; absent keys are
    /// no-ops. Engines override this to amortize fixed costs the way
    /// `put_batch` does — the write engine's lazy-allocation deletes
    /// (all-zero cuboids) would otherwise pay one positioning cost per
    /// key. Default: loop over `delete`.
    fn delete_batch(&self, table: &str, keys: &[u64]) -> Result<()> {
        for &k in keys {
            self.delete(table, k)?;
        }
        Ok(())
    }

    /// Read many keys. Default: loop over `get`.
    fn get_batch(&self, table: &str, keys: &[u64]) -> Result<Vec<Option<Blob>>> {
        keys.iter().map(|&k| self.get(table, k)).collect()
    }

    /// Write many values in one transaction-like batch.
    fn put_batch(&self, table: &str, items: &[(u64, Vec<u8>)]) -> Result<()> {
        for (k, v) in items {
            self.put(table, *k, v)?;
        }
        Ok(())
    }

    /// Read every present key in `[start, start + len)` — the contiguous
    /// Morton-run read. Returns (key, value) pairs in key order.
    fn get_run(&self, table: &str, start: u64, len: u64) -> Result<Vec<(u64, Blob)>>;

    /// All keys in a table, ascending (migration, hierarchy builds).
    fn keys(&self, table: &str) -> Result<Vec<u64>>;

    /// Tables present in the engine.
    fn tables(&self) -> Result<Vec<String>>;

    /// Cumulative stats.
    fn stats(&self) -> &IoStats;

    /// Flush durable state (no-op for memory engines).
    fn sync(&self) -> Result<()> {
        Ok(())
    }

    /// The Morton partition behind this engine, if it is sharded — the
    /// parallel cutout engine aligns its fan-out batches to these shard
    /// boundaries so each worker's run lands wholly on one node. Returned
    /// as a shared snapshot because a sharded engine's map is a living
    /// object: a split or live move swaps it, and callers plan against
    /// one consistent generation.
    fn shard_map(&self) -> Option<Arc<crate::shard::ShardMap>> {
        None
    }

    /// Deterministic fault hooks, when the engine has them (the simulated
    /// store's crash / transient-error controls). `None` for real engines;
    /// the failover test harness uses this to kill nodes without
    /// downcasting through the `Engine` trait object.
    fn fault_injector(&self) -> Option<&FaultInjector> {
        None
    }
}

/// Shared handle to any engine.
pub type Engine = Arc<dyn StorageEngine>;

/// Copy every table (or one table) from `src` to `dst` — the
/// dump-and-restore migration the paper performs when an annotation
/// project stops being actively written and moves off the SSD node
/// (§4.1 "Data Distribution").
pub fn migrate(src: &dyn StorageEngine, dst: &dyn StorageEngine, table: Option<&str>) -> Result<u64> {
    let tables = match table {
        Some(t) => vec![t.to_string()],
        None => src.tables()?,
    };
    let mut moved = 0u64;
    for t in tables {
        let keys = src.keys(&t)?;
        // Dump in key order (sequential source scan), restore as batches.
        let mut batch = Vec::with_capacity(256);
        for k in keys {
            if let Some(v) = src.get(&t, k)? {
                batch.push((k, (*v).clone()));
                moved += 1;
            }
            if batch.len() >= 256 {
                dst.put_batch(&t, &batch)?;
                batch.clear();
            }
        }
        if !batch.is_empty() {
            dst.put_batch(&t, &batch)?;
        }
    }
    Ok(moved)
}

/// [`migrate`], scoped to keys in `[lo, hi)` of one table — the shard
/// move's copy step ships only the half that changes owner instead of
/// the whole table. `hi == u64::MAX` is open-ended, matching
/// [`crate::shard::ShardMap::shard_range`]'s last shard.
pub fn migrate_range(
    src: &dyn StorageEngine,
    dst: &dyn StorageEngine,
    table: &str,
    lo: u64,
    hi: u64,
) -> Result<u64> {
    let in_range = |k: u64| k >= lo && (k < hi || hi == u64::MAX);
    let mut moved = 0u64;
    let mut batch = Vec::with_capacity(256);
    for k in src.keys(table)? {
        if !in_range(k) {
            continue;
        }
        if let Some(v) = src.get(table, k)? {
            batch.push((k, (*v).clone()));
            moved += 1;
        }
        if batch.len() >= 256 {
            dst.put_batch(table, &batch)?;
            batch.clear();
        }
    }
    if !batch.is_empty() {
        dst.put_batch(table, &batch)?;
    }
    Ok(moved)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// Engine conformance suite, run against every implementation.
    pub(crate) fn conformance(engine: &dyn StorageEngine) {
        let t = "conf/test";
        assert_eq!(engine.get(t, 1).unwrap(), None);
        engine.put(t, 1, b"one").unwrap();
        engine.put(t, 5, b"five").unwrap();
        engine.put(t, 3, b"three").unwrap();
        assert_eq!(**engine.get(t, 1).unwrap().unwrap(), *b"one");
        assert_eq!(engine.get(t, 2).unwrap(), None);

        // Replace.
        engine.put(t, 1, b"uno").unwrap();
        assert_eq!(**engine.get(t, 1).unwrap().unwrap(), *b"uno");

        // Batch get preserves order and gaps.
        let b = engine.get_batch(t, &[5, 2, 1]).unwrap();
        assert_eq!(b[0].as_deref().map(|v| &v[..]), Some(b"five".as_ref()));
        assert_eq!(b[1], None);
        assert_eq!(b[2].as_deref().map(|v| &v[..]), Some(b"uno".as_ref()));

        // Run read: keys in [1, 6) present = 1, 3, 5.
        let run = engine.get_run(t, 1, 5).unwrap();
        assert_eq!(run.iter().map(|(k, _)| *k).collect::<Vec<_>>(), vec![1, 3, 5]);

        // Keys ascending.
        assert_eq!(engine.keys(t).unwrap(), vec![1, 3, 5]);

        // Delete.
        engine.delete(t, 3).unwrap();
        assert_eq!(engine.get(t, 3).unwrap(), None);
        engine.delete(t, 3).unwrap(); // idempotent

        // Batch put.
        let items: Vec<(u64, Vec<u8>)> = (10..20).map(|k| (k, vec![k as u8; 8])).collect();
        engine.put_batch(t, &items).unwrap();
        let run = engine.get_run(t, 10, 10).unwrap();
        assert_eq!(run.len(), 10);

        // Batch delete: present and absent keys mix freely.
        engine.delete_batch(t, &[10, 11, 12, 999]).unwrap();
        assert_eq!(engine.get_run(t, 10, 10).unwrap().len(), 7);
        engine.delete_batch(t, &[]).unwrap(); // empty batch is a no-op

        // Table list contains ours.
        assert!(engine.tables().unwrap().iter().any(|x| x == t));

        // Stats moved.
        let s = engine.stats().snapshot();
        assert!(s.reads > 0 && s.writes > 0);
    }

    #[test]
    fn migrate_moves_everything() {
        let a = MemStore::new();
        let b = MemStore::new();
        for k in 0..100u64 {
            a.put("tbl", k, &k.to_le_bytes()).unwrap();
        }
        a.put("other", 7, b"x").unwrap();
        let moved = migrate(&a, &b, None).unwrap();
        assert_eq!(moved, 101);
        assert_eq!(**b.get("tbl", 42).unwrap().unwrap(), 42u64.to_le_bytes());
        assert_eq!(**b.get("other", 7).unwrap().unwrap(), *b"x");
        // Single-table migration.
        let c = MemStore::new();
        assert_eq!(migrate(&a, &c, Some("other")).unwrap(), 1);
        assert_eq!(c.get("tbl", 0).unwrap(), None);
    }

    #[test]
    fn migrate_range_ships_only_the_window() {
        let a = MemStore::new();
        let b = MemStore::new();
        for k in 0..100u64 {
            a.put("tbl", k, &k.to_le_bytes()).unwrap();
        }
        // The moving half only: [40, 60).
        assert_eq!(migrate_range(&a, &b, "tbl", 40, 60).unwrap(), 20);
        assert_eq!(b.get("tbl", 39).unwrap(), None);
        assert_eq!(**b.get("tbl", 40).unwrap().unwrap(), 40u64.to_le_bytes());
        assert_eq!(**b.get("tbl", 59).unwrap().unwrap(), 59u64.to_le_bytes());
        assert_eq!(b.get("tbl", 60).unwrap(), None);
        // The source keeps everything — migrate copies, retire deletes.
        assert_eq!(a.keys("tbl").unwrap().len(), 100);
    }

    #[test]
    fn migrate_range_empty_window_moves_nothing() {
        let a = MemStore::new();
        let b = MemStore::new();
        for k in 0..10u64 {
            a.put("tbl", k, b"v").unwrap();
        }
        // Empty ranges: degenerate [5, 5) and a window past the data.
        assert_eq!(migrate_range(&a, &b, "tbl", 5, 5).unwrap(), 0);
        assert_eq!(migrate_range(&a, &b, "tbl", 500, 600).unwrap(), 0);
        assert!(b.keys("tbl").unwrap().is_empty());
        // An absent table is also empty, not an error.
        assert_eq!(migrate_range(&a, &b, "ghost", 0, 100).unwrap(), 0);
    }

    #[test]
    fn migrate_range_boundaries_are_half_open() {
        let a = MemStore::new();
        let b = MemStore::new();
        // Keys straddling both boundaries: lo is included, hi excluded.
        for k in [9u64, 10, 11, 19, 20, 21] {
            a.put("tbl", k, &k.to_le_bytes()).unwrap();
        }
        assert_eq!(migrate_range(&a, &b, "tbl", 10, 20).unwrap(), 3);
        assert_eq!(b.keys("tbl").unwrap(), vec![10, 11, 19]);
        // Open-ended hi == u64::MAX includes the top key itself.
        let c = MemStore::new();
        c.put("tbl", u64::MAX, b"top").unwrap();
        c.put("tbl", 0, b"bottom").unwrap();
        let d = MemStore::new();
        assert_eq!(migrate_range(&c, &d, "tbl", 1, u64::MAX).unwrap(), 1);
        assert_eq!(**d.get("tbl", u64::MAX).unwrap().unwrap(), *b"top");
    }
}
