//! In-memory storage engine: B-tree tables under an RwLock.
//!
//! Serves as the "data in memory" configuration of the paper's evaluation
//! (Figure 10 "aligned memory") and as the content store under
//! [`super::sim::SimulatedStore`].

use std::collections::BTreeMap;
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::storage::{Blob, IoStats, StorageEngine};
use crate::Result;

type Table = BTreeMap<u64, Blob>;

/// In-memory engine. Values are `Arc`-shared so concurrent readers never
/// copy under the lock.
pub struct MemStore {
    tables: RwLock<HashMap<String, Table>>,
    stats: IoStats,
}

impl MemStore {
    pub fn new() -> Self {
        MemStore { tables: RwLock::new(HashMap::new()), stats: IoStats::default() }
    }

    /// Total stored bytes (capacity accounting for migration decisions).
    pub fn stored_bytes(&self) -> u64 {
        let t = self.tables.read().unwrap();
        t.values()
            .map(|tab| tab.values().map(|v| v.len() as u64).sum::<u64>())
            .sum()
    }

    /// Number of stored values across all tables.
    pub fn stored_values(&self) -> u64 {
        let t = self.tables.read().unwrap();
        t.values().map(|tab| tab.len() as u64).sum()
    }
}

impl Default for MemStore {
    fn default() -> Self {
        Self::new()
    }
}

impl StorageEngine for MemStore {
    fn name(&self) -> &str {
        "mem"
    }

    fn get(&self, table: &str, key: u64) -> Result<Option<Blob>> {
        let tables = self.tables.read().unwrap();
        let v = tables.get(table).and_then(|t| t.get(&key)).map(Arc::clone);
        match &v {
            Some(v) => self.stats.record_read(v.len()),
            None => self.stats.record_miss(),
        }
        Ok(v)
    }

    fn put(&self, table: &str, key: u64, value: &[u8]) -> Result<()> {
        self.stats.record_write(value.len());
        let mut tables = self.tables.write().unwrap();
        tables
            .entry(table.to_string())
            .or_default()
            .insert(key, Arc::new(value.to_vec()));
        Ok(())
    }

    fn delete(&self, table: &str, key: u64) -> Result<()> {
        let mut tables = self.tables.write().unwrap();
        if let Some(t) = tables.get_mut(table) {
            t.remove(&key);
        }
        Ok(())
    }

    fn delete_batch(&self, table: &str, keys: &[u64]) -> Result<()> {
        // One lock acquisition for the whole batch.
        let mut tables = self.tables.write().unwrap();
        if let Some(t) = tables.get_mut(table) {
            for k in keys {
                t.remove(k);
            }
        }
        Ok(())
    }

    fn get_batch(&self, table: &str, keys: &[u64]) -> Result<Vec<Option<Blob>>> {
        let tables = self.tables.read().unwrap();
        let t = tables.get(table);
        Ok(keys
            .iter()
            .map(|k| {
                let v = t.and_then(|t| t.get(k)).map(Arc::clone);
                match &v {
                    Some(v) => self.stats.record_read(v.len()),
                    None => self.stats.record_miss(),
                }
                v
            })
            .collect())
    }

    fn put_batch(&self, table: &str, items: &[(u64, Vec<u8>)]) -> Result<()> {
        let mut tables = self.tables.write().unwrap();
        let t = tables.entry(table.to_string()).or_default();
        for (k, v) in items {
            self.stats.record_write(v.len());
            t.insert(*k, Arc::new(v.clone()));
        }
        Ok(())
    }

    fn get_run(&self, table: &str, start: u64, len: u64) -> Result<Vec<(u64, Blob)>> {
        self.stats.record_run_read();
        let tables = self.tables.read().unwrap();
        let Some(t) = tables.get(table) else { return Ok(Vec::new()) };
        let end = start.saturating_add(len);
        let out: Vec<(u64, Blob)> = t
            .range(start..end)
            .map(|(k, v)| {
                self.stats.record_read(v.len());
                (*k, Arc::clone(v))
            })
            .collect();
        Ok(out)
    }

    fn keys(&self, table: &str) -> Result<Vec<u64>> {
        let tables = self.tables.read().unwrap();
        Ok(tables.get(table).map(|t| t.keys().copied().collect()).unwrap_or_default())
    }

    fn tables(&self) -> Result<Vec<String>> {
        let tables = self.tables.read().unwrap();
        let mut names: Vec<String> = tables.keys().cloned().collect();
        names.sort();
        Ok(names)
    }

    fn stats(&self) -> &IoStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conformance() {
        crate::storage::tests::conformance(&MemStore::new());
    }

    #[test]
    fn accounting() {
        let m = MemStore::new();
        m.put("a", 1, &[0u8; 100]).unwrap();
        m.put("b", 2, &[0u8; 50]).unwrap();
        assert_eq!(m.stored_bytes(), 150);
        assert_eq!(m.stored_values(), 2);
        m.put("a", 1, &[0u8; 10]).unwrap(); // replace shrinks
        assert_eq!(m.stored_bytes(), 60);
    }

    #[test]
    fn concurrent_readers_and_writers() {
        let m = std::sync::Arc::new(MemStore::new());
        std::thread::scope(|s| {
            for w in 0..4u64 {
                let m = std::sync::Arc::clone(&m);
                s.spawn(move || {
                    for i in 0..500u64 {
                        m.put("t", w * 1000 + i, &i.to_le_bytes()).unwrap();
                    }
                });
            }
            for _ in 0..4 {
                let m = std::sync::Arc::clone(&m);
                s.spawn(move || {
                    for i in 0..500u64 {
                        let _ = m.get("t", i).unwrap();
                    }
                });
            }
        });
        assert_eq!(m.stored_values(), 2000);
    }
}
