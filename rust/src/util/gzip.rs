//! Gzip compression helpers for cuboid payloads.
//!
//! The paper gzip-compresses cube data on disk (§3.2): EM image data has
//! high entropy and compresses <10%; annotation labels have low entropy
//! (many zeros, long runs) and compress extremely well. We reproduce both
//! behaviours, and additionally provide the run-length codec the paper
//! cites as possible future work ([1, 44]) so the ablation bench can
//! compare them.

use std::io::{Read, Write};

use flate2::read::GzDecoder;
use flate2::write::GzEncoder;
use flate2::Compression;

use crate::{Error, Result};

/// Compress with gzip at the given level (paper default behaviour: level 6).
pub fn compress(data: &[u8], level: u32) -> Result<Vec<u8>> {
    let mut enc = GzEncoder::new(Vec::with_capacity(data.len() / 2), Compression::new(level));
    enc.write_all(data)?;
    enc.finish().map_err(Error::from)
}

/// Decompress a gzip stream. `size_hint` pre-sizes the output buffer (the
/// cuboid shape is known from the level config, so the exact size is known).
pub fn decompress(data: &[u8], size_hint: usize) -> Result<Vec<u8>> {
    let mut dec = GzDecoder::new(data);
    let mut out = Vec::with_capacity(size_hint);
    dec.read_to_end(&mut out)?;
    Ok(out)
}

/// Run-length encode 32-bit words (annotation labels). Format: repeated
/// (varint run_length, u32 value) pairs. Wins over gzip for long
/// single-label runs; the ablation bench quantifies the tradeoff.
pub fn rle32_encode(words: &[u32]) -> Vec<u8> {
    let mut e = crate::util::codec::Enc::with_capacity(words.len() / 8 + 16);
    let mut i = 0usize;
    while i < words.len() {
        let v = words[i];
        let mut j = i + 1;
        while j < words.len() && words[j] == v {
            j += 1;
        }
        e.varint((j - i) as u64);
        e.u32(v);
        i = j;
    }
    e.finish()
}

/// Decode [`rle32_encode`] output; `count` is the expected word count.
pub fn rle32_decode(data: &[u8], count: usize) -> Result<Vec<u32>> {
    let mut d = crate::util::codec::Dec::new(data);
    let mut out = Vec::with_capacity(count);
    while !d.done() {
        let run = d.varint()? as usize;
        let v = d.u32()?;
        if out.len() + run > count {
            return Err(Error::Codec("rle32 overrun".into()));
        }
        out.resize(out.len() + run, v);
    }
    if out.len() != count {
        return Err(Error::Codec(format!("rle32 short: {} of {count}", out.len())));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn gzip_roundtrip() {
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        let c = compress(&data, 6).unwrap();
        assert!(c.len() < data.len());
        assert_eq!(decompress(&c, data.len()).unwrap(), data);
    }

    #[test]
    fn gzip_empty() {
        let c = compress(&[], 6).unwrap();
        assert_eq!(decompress(&c, 0).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn annotation_like_data_compresses_well() {
        // Low-entropy labels: long zero runs + labeled regions — the §3.2
        // claim that cube labels compress well.
        let mut words = vec![0u32; 1 << 16];
        for i in 20_000..30_000 {
            words[i] = 42;
        }
        let bytes: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
        let c = compress(&bytes, 6).unwrap();
        assert!(c.len() * 50 < bytes.len(), "expected >50x on labels, got {}", c.len());
    }

    #[test]
    fn em_like_data_compresses_poorly() {
        // High-entropy image data: <10% reduction (§5).
        let mut rng = Rng::new(11);
        let data: Vec<u8> = (0..1 << 16).map(|_| rng.next_u32() as u8).collect();
        let c = compress(&data, 6).unwrap();
        assert!(c.len() as f64 > data.len() as f64 * 0.9);
    }

    #[test]
    fn rle_roundtrip_runs() {
        let mut words = vec![0u32; 4096];
        words[100..200].fill(7);
        words[4000..4096].fill(123456);
        let e = rle32_encode(&words);
        assert!(e.len() < 64);
        assert_eq!(rle32_decode(&e, words.len()).unwrap(), words);
    }

    #[test]
    fn rle_roundtrip_random() {
        let mut rng = Rng::new(12);
        let words: Vec<u32> = (0..2048).map(|_| rng.below(4) as u32).collect();
        let e = rle32_encode(&words);
        assert_eq!(rle32_decode(&e, words.len()).unwrap(), words);
    }

    #[test]
    fn rle_wrong_count_errors() {
        let words = vec![5u32; 16];
        let e = rle32_encode(&words);
        assert!(rle32_decode(&e, 15).is_err());
        assert!(rle32_decode(&e, 17).is_err());
    }
}
