//! A small, fast, deterministic PRNG (xoshiro256**) used by the synthetic
//! data generator, the workload generators, and the property tester.
//!
//! Deterministic seeding keeps every bench table and property-test run
//! exactly reproducible.

/// xoshiro256** by Blackman & Vigna — public domain reference algorithm.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed (SplitMix64 expansion, so any seed —
    /// including 0 — yields a well-mixed state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift; unbiased enough for
    /// synthetic data and workload generation).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniformity_rough() {
        let mut r = Rng::new(5);
        let mut buckets = [0u32; 10];
        for _ in 0..100_000 {
            buckets[r.below(10) as usize] += 1;
        }
        for &b in &buckets {
            assert!((8_000..12_000).contains(&b), "bucket {b} out of tolerance");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(8);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
