//! Small shared utilities: PRNG, thread pool, binary codec, gzip, and an
//! in-repo property-testing mini-framework.
//!
//! The offline vendor set has no `rand`, `rayon`, `serde` or `proptest`, so
//! these live here (see DESIGN.md §1).

pub mod codec;
pub mod gzip;
pub mod pool;
pub mod prop;
pub mod rng;

pub use pool::ThreadPool;
pub use rng::Rng;
