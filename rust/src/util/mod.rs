//! Small shared utilities: PRNG, thread pool, binary codec, gzip, and an
//! in-repo property-testing mini-framework.
//!
//! The offline vendor set has no `rand`, `rayon`, `serde` or `proptest`, so
//! these live here (see DESIGN.md §1).

pub mod codec;
pub mod gzip;
pub mod pool;
pub mod prop;
pub mod rng;

pub use pool::ThreadPool;
pub use rng::Rng;

/// FNV-1a over the concatenation of `parts` — the stable, dependency-free
/// hash shared by cluster placement and the cuboid cache's key mixing.
///
/// ```
/// let a = ocpd::util::fnv1a(&[b"table", &7u64.to_le_bytes()]);
/// let b = ocpd::util::fnv1a(&[b"table", &8u64.to_le_bytes()]);
/// assert_ne!(a, b);
/// assert_eq!(a, ocpd::util::fnv1a(&[b"table", &7u64.to_le_bytes()]));
/// ```
pub fn fnv1a(parts: &[&[u8]]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for part in parts {
        for &b in *part {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}
