//! A fixed-size worker thread pool with a bounded queue and a scoped
//! fork-join helper.
//!
//! The paper's application servers run each Web-service request on a single
//! process thread and realize throughput through request-level parallelism
//! (§5, Fig 11). This pool is the analogue: the web server, the cutout
//! assembler, and the vision pipeline all submit per-request / per-cuboid
//! work items here.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool.
pub struct ThreadPool {
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `n` workers (min 1) with a queue bound of `4 * n`.
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = sync_channel::<Job>(4 * n);
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                let inflight = Arc::clone(&inflight);
                std::thread::Builder::new()
                    .name(format!("ocpd-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                inflight.fetch_sub(1, Ordering::Release);
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, inflight }
    }

    /// Pool with one worker per available core.
    pub fn per_core() -> Self {
        Self::new(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4))
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget job. Blocks if the queue is full
    /// (backpressure).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inflight.fetch_add(1, Ordering::Acquire);
        self.tx.as_ref().expect("pool live").send(Box::new(f)).expect("pool send");
    }

    /// Jobs submitted but not yet completed.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        while self.inflight() > 0 {
            std::thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(i)` for `i in 0..n` across up to `par` scoped threads and collect
/// results in order. Panics propagate. This is the fork-join primitive used
/// on the cutout hot path (one task per cuboid batch) — scoped, so tasks
/// may borrow from the caller.
pub fn scoped_map<T, F>(n: usize, par: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let par = par.max(1).min(n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if par == 1 {
        return (0..n).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = out.as_mut_ptr() as usize;
    // Carry the caller's trace and QoS contexts onto the workers so
    // spans opened inside `f` attach to the request's trace, and fair
    // gates / deadline checks see the request's class, tenant, and
    // deadline rather than nothing.
    let trace_ctx = crate::obs::trace::current();
    let qos_ctx = crate::qos::ctx::current();
    std::thread::scope(|s| {
        for _ in 0..par {
            let next = &next;
            let f = &f;
            let trace_ctx = trace_ctx.clone();
            let qos_ctx = qos_ctx.clone();
            s.spawn(move || {
                let _trace = crate::obs::trace::install(trace_ctx);
                let _qos = crate::qos::ctx::install(qos_ctx);
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(i);
                    // Each index is claimed exactly once, so the write
                    // is exclusive; Vec storage outlives the scope.
                    unsafe {
                        let base = slots as *mut Option<T>;
                        *base.add(i) = Some(v);
                    }
                }
            });
        }
    });
    out.into_iter().map(|v| v.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn pool_shutdown_joins() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn scoped_map_ordered() {
        let out = scoped_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_empty_and_serial() {
        assert!(scoped_map(0, 4, |i| i).is_empty());
        assert_eq!(scoped_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn scoped_map_borrows() {
        let data: Vec<u64> = (0..1000).collect();
        let sums = scoped_map(10, 4, |i| data[i * 100..(i + 1) * 100].iter().sum::<u64>());
        assert_eq!(sums.iter().sum::<u64>(), data.iter().sum::<u64>());
    }
}
