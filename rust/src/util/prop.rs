//! In-repo property-testing mini-framework.
//!
//! The offline vendor set has no `proptest`, so this module provides the
//! subset we need: seeded generators, a case loop that reports the failing
//! seed, and greedy input shrinking for integer tuples. Coordinator
//! invariants (Morton round-trips, cutout assembly, routing, write
//! disciplines) are property-tested with this.
//!
//! ```no_run
//! // (no_run: doctest executables can't resolve the xla_extension rpath)
//! use ocpd::util::prop::{property, Gen};
//! property("add_commutes", 200, |g| {
//!     let a = g.u64_below(1000);
//!     let b = g.u64_below(1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::Rng;

/// Per-case input generator. Records draws so failures are reproducible
/// from the printed seed.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), seed }
    }

    /// Uniform u64 in `[0, n)`.
    pub fn u64_below(&mut self, n: u64) -> u64 {
        self.rng.below(n)
    }

    /// Uniform u64 in `[lo, hi)`.
    pub fn u64_range(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range(lo, hi)
    }

    /// Uniform u32 in `[0, n)`.
    pub fn u32_below(&mut self, n: u32) -> u32 {
        self.rng.below(n as u64) as u32
    }

    /// Uniform usize in `[0, n)`.
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.rng.below(n as u64) as usize
    }

    /// f64 in `[0,1)`.
    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    /// Bernoulli.
    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// A random axis-aligned box `[lo, hi)` within `dims` with each extent
    /// in `[1, max_extent]`. The workhorse generator for spatial
    /// properties.
    pub fn boxed(&mut self, dims: [u64; 3], max_extent: u64) -> ([u64; 3], [u64; 3]) {
        let mut lo = [0u64; 3];
        let mut hi = [0u64; 3];
        for a in 0..3 {
            let ext = 1 + self.rng.below(max_extent.min(dims[a]));
            let start = self.rng.below(dims[a] - ext + 1);
            lo[a] = start;
            hi[a] = start + ext;
        }
        (lo, hi)
    }

    /// A vector of `len` draws from `[0, bound)`.
    pub fn vec_u64(&mut self, len: usize, bound: u64) -> Vec<u64> {
        (0..len).map(|_| self.rng.below(bound)).collect()
    }

    /// Underlying RNG for custom draws.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `f` against `cases` generated inputs. On panic, re-raises with the
/// case seed in the message so the failure replays deterministically:
/// `Gen::new(seed)` reproduces the exact inputs.
pub fn property<F: Fn(&mut Gen)>(name: &str, cases: u64, f: F) {
    // Fixed base seed: CI-stable. Override with OCPD_PROP_SEED for fuzzing.
    let base = std::env::var("OCPD_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x0C9D_2013u64);
    for case in 0..cases {
        let seed = base.wrapping_mul(0x9E37_79B9).wrapping_add(case);
        // AssertUnwindSafe: on failure we panic immediately with the
        // seed — state observed after a failed case is never reused.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(seed);
            f(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "panic".into());
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        property("reverse_involutive", 100, |g| {
            let n = g.usize_below(32);
            let v = g.vec_u64(n, 1000);
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            assert_eq!(r, v);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_fails'")]
    fn reports_seed_on_failure() {
        property("always_fails", 10, |_g| {
            panic!("boom");
        });
    }

    #[test]
    fn boxed_within_dims() {
        property("boxed_bounds", 500, |g| {
            let dims = [1 + g.u64_below(512), 1 + g.u64_below(512), 1 + g.u64_below(64)];
            let (lo, hi) = g.boxed(dims, 64);
            for a in 0..3 {
                assert!(lo[a] < hi[a]);
                assert!(hi[a] <= dims[a]);
            }
        });
    }
}
