//! Minimal little-endian binary encode/decode helpers.
//!
//! The vendor set has no `serde`, so persistent records (RAMON metadata,
//! spatial-index blobs, `ocpk` interchange frames) use this hand-rolled
//! codec. Encodings are versioned by their containing record, length-
//! prefixed, and deliberately boring.

use crate::{Error, Result};

/// IEEE CRC-32 lookup table (reflected polynomial 0xEDB88320), built at
/// compile time.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// IEEE CRC-32 of `data` — the checksum guarding WAL record frames
/// ([`crate::wal`]) against torn writes and bit rot.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xffff_ffffu32;
    for &b in data {
        c = CRC32_TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    c ^ 0xffff_ffff
}

/// Append-only encoder.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Self {
        Enc { buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Enc { buf: Vec::with_capacity(n) }
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn u16(&mut self, v: u16) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f32(&mut self, v: f32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    /// LEB128 variable-length unsigned integer.
    pub fn varint(&mut self, mut v: u64) -> &mut Self {
        loop {
            let byte = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(byte);
                break;
            }
            self.buf.push(byte | 0x80);
        }
        self
    }

    /// Length-prefixed byte string.
    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.varint(v.len() as u64);
        self.buf.extend_from_slice(v);
        self
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, v: &str) -> &mut Self {
        self.bytes(v.as_bytes())
    }

    /// Length-prefixed list of u32.
    pub fn u32s(&mut self, vs: &[u32]) -> &mut Self {
        self.varint(vs.len() as u64);
        for &v in vs {
            self.u32(v);
        }
        self
    }

    /// Length-prefixed, delta-varint-encoded sorted u64 list (spatial index
    /// blobs: Morton codes compress very well this way).
    pub fn sorted_u64s(&mut self, vs: &[u64]) -> &mut Self {
        self.varint(vs.len() as u64);
        let mut prev = 0u64;
        for &v in vs {
            debug_assert!(v >= prev, "sorted_u64s requires sorted input");
            self.varint(v - prev);
            prev = v;
        }
        self
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Cursor-based decoder.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Codec(format!(
                "decode overrun: need {n} bytes at {} of {}",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let b = self.u8()?;
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
            if shift >= 64 {
                return Err(Error::Codec("varint too long".into()));
            }
        }
    }

    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.varint()? as usize;
        self.take(n)
    }

    pub fn str(&mut self) -> Result<String> {
        let b = self.bytes()?;
        String::from_utf8(b.to_vec()).map_err(|e| Error::Codec(format!("bad utf8: {e}")))
    }

    pub fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.varint()? as usize;
        let mut out = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            out.push(self.u32()?);
        }
        Ok(out)
    }

    pub fn sorted_u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.varint()? as usize;
        let mut out = Vec::with_capacity(n.min(1 << 20));
        let mut prev = 0u64;
        for _ in 0..n {
            prev += self.varint()?;
            out.push(prev);
        }
        Ok(out)
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn done(&self) -> bool {
        self.remaining() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_scalars() {
        let mut e = Enc::new();
        e.u8(7).u16(300).u32(70_000).u64(1 << 40).f32(1.5).f64(-2.25).str("synapse");
        let b = e.finish();
        let mut d = Dec::new(&b);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u16().unwrap(), 300);
        assert_eq!(d.u32().unwrap(), 70_000);
        assert_eq!(d.u64().unwrap(), 1 << 40);
        assert_eq!(d.f32().unwrap(), 1.5);
        assert_eq!(d.f64().unwrap(), -2.25);
        assert_eq!(d.str().unwrap(), "synapse");
        assert!(d.done());
    }

    #[test]
    fn varint_roundtrip_edges() {
        for v in [0u64, 1, 127, 128, 16_383, 16_384, u32::MAX as u64, u64::MAX] {
            let mut e = Enc::new();
            e.varint(v);
            let b = e.finish();
            assert_eq!(Dec::new(&b).varint().unwrap(), v, "value {v}");
        }
    }

    #[test]
    fn varint_roundtrip_random() {
        let mut rng = Rng::new(42);
        for _ in 0..5_000 {
            let v = rng.next_u64() >> rng.below(64) as u32;
            let mut e = Enc::new();
            e.varint(v);
            let b = e.finish();
            assert_eq!(Dec::new(&b).varint().unwrap(), v);
        }
    }

    #[test]
    fn sorted_u64s_compact_and_roundtrip() {
        let vs: Vec<u64> = (0..1000u64).map(|i| i * 3).collect();
        let mut e = Enc::new();
        e.sorted_u64s(&vs);
        let b = e.finish();
        // Delta coding: ~1 byte per element for small gaps.
        assert!(b.len() < 1200, "blob too large: {}", b.len());
        assert_eq!(Dec::new(&b).sorted_u64s().unwrap(), vs);
    }

    #[test]
    fn overrun_is_error_not_panic() {
        let b = vec![1u8, 2];
        let mut d = Dec::new(&b);
        assert!(d.u64().is_err());
        let mut d2 = Dec::new(&[0x80u8; 12]);
        assert!(d2.varint().is_err(), "unterminated varint must error");
    }

    #[test]
    fn crc32_known_vectors() {
        // The standard IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
        // Sensitive to single-bit corruption.
        assert_ne!(crc32(b"123456789"), crc32(b"123456788"));
    }

    #[test]
    fn bytes_roundtrip() {
        let mut e = Enc::new();
        e.bytes(&[1, 2, 3]).bytes(&[]).u32s(&[9, 8, 7]);
        let b = e.finish();
        let mut d = Dec::new(&b);
        assert_eq!(d.bytes().unwrap(), &[1, 2, 3]);
        assert_eq!(d.bytes().unwrap(), &[] as &[u8]);
        assert_eq!(d.u32s().unwrap(), vec![9, 8, 7]);
    }
}
