//! [`CuboidCache`]: a sharded, byte-budgeted LRU over framed cuboid
//! blobs, sitting between the cutout read path and the storage engine.
//!
//! The production OCP service survived real traffic because hot cutout
//! regions were served from memory rather than the disk arrays (Burns et
//! al. 2018 highlight the caching tier as what made the ecosystem
//! scale). This cache reproduces that tier:
//!
//! * **Keying** — entries are keyed by `(cuboid table, Morton code)`;
//!   the table name (`{project}/cub/{res}/{channel}`) already encodes
//!   project, resolution and channel, so one cache serves every level of
//!   one project.
//! * **Sharding** — N independently-locked shards selected by key hash,
//!   so concurrent readers on the parallel cutout engine do not convoy
//!   on one mutex.
//! * **Byte budget** — each shard holds `capacity_bytes / shards`;
//!   insertion evicts least-recently-used entries until the new blob
//!   fits. Negative entries (known-absent cuboids, the lazy-allocation
//!   case) are cached too, at a small fixed charge, so warm reads of
//!   sparse regions never touch the engine.
//! * **Invalidation protocol** — writers call [`CuboidCache::invalidate`]
//!   *after* the engine write; the WAL flusher invalidates each key it
//!   drains. Readers snapshot the shard's invalidation [`epoch`]
//!   *before* fetching from the engine and populate with
//!   [`insert_if`], which refuses the insert when the epoch moved — so
//!   a read racing a write can never install a stale blob over the
//!   invalidation (it just declines to cache).
//!
//! [`epoch`]: CuboidCache::epoch
//! [`insert_if`]: CuboidCache::insert_if

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

use crate::metrics::Counter;
use crate::storage::Blob;

/// Charged size of a negative (known-absent) entry.
const NEG_ENTRY_BYTES: usize = 64;

/// Tuning knobs for one project's cuboid cache.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    /// Independently-locked shards (power of two recommended).
    pub shards: usize,
    /// Total byte budget across all shards.
    pub capacity_bytes: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig { shards: 8, capacity_bytes: 64 << 20 }
    }
}

/// Hit/miss/churn counters, exported through `/cache/status`.
#[derive(Debug, Default)]
pub struct CacheMetrics {
    pub hits: Counter,
    pub misses: Counter,
    pub inserts: Counter,
    pub evictions: Counter,
    pub invalidations: Counter,
}

/// Point-in-time summary of one cache (the `/cache/status` row).
#[derive(Clone, Debug, Default)]
pub struct CacheStatus {
    pub entries: u64,
    pub bytes: u64,
    pub capacity_bytes: u64,
    pub shards: u64,
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    pub invalidations: u64,
}

impl CacheStatus {
    /// Hit fraction of all lookups so far (0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry {
    /// Full key, kept to disambiguate 64-bit hash collisions.
    table: String,
    code: u64,
    /// `None` = known-absent cuboid (negative entry).
    value: Option<Blob>,
    charged: usize,
    tick: u64,
}

#[derive(Default)]
struct Shard {
    /// Keyed by the FNV mix of `(table, code)`.
    map: HashMap<u64, Entry>,
    /// LRU order: tick → map key. Ticks are unique per shard.
    lru: BTreeMap<u64, u64>,
    bytes: usize,
    next_tick: u64,
    /// Bumped on every invalidation; guards [`CuboidCache::insert_if`].
    epoch: u64,
}

impl Shard {
    fn touch(&mut self, hash: u64) {
        let tick = self.next_tick;
        self.next_tick += 1;
        if let Some(e) = self.map.get_mut(&hash) {
            self.lru.remove(&e.tick);
            e.tick = tick;
            self.lru.insert(tick, hash);
        }
    }

    fn remove(&mut self, hash: u64) -> Option<Entry> {
        let e = self.map.remove(&hash)?;
        self.lru.remove(&e.tick);
        self.bytes -= e.charged;
        Some(e)
    }
}

/// Sharded LRU cuboid cache. Cheap to share (`Arc`); all methods take
/// `&self`.
pub struct CuboidCache {
    shards: Vec<Mutex<Shard>>,
    per_shard_bytes: usize,
    capacity_bytes: usize,
    pub metrics: CacheMetrics,
}

/// FNV-1a over the table bytes, mixed with the Morton code.
fn key_hash(table: &str, code: u64) -> u64 {
    crate::util::fnv1a(&[table.as_bytes(), &code.to_le_bytes()])
}

impl CuboidCache {
    pub fn new(cfg: CacheConfig) -> Self {
        let n = cfg.shards.max(1);
        CuboidCache {
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            per_shard_bytes: (cfg.capacity_bytes / n).max(1),
            capacity_bytes: cfg.capacity_bytes,
            metrics: CacheMetrics::default(),
        }
    }

    fn shard_of(&self, hash: u64) -> &Mutex<Shard> {
        // High bits: the low bits already picked the FNV lanes.
        &self.shards[(hash >> 32) as usize % self.shards.len()]
    }

    /// Look up one cuboid. `None` = not cached; `Some(None)` =
    /// known-absent (negative hit); `Some(Some(blob))` = positive hit.
    pub fn get(&self, table: &str, code: u64) -> Option<Option<Blob>> {
        let hash = key_hash(table, code);
        let mut sh = self.shard_of(hash).lock().unwrap();
        let hit = match sh.map.get(&hash) {
            Some(e) if e.table == table && e.code == code => Some(e.value.clone()),
            _ => None,
        };
        match hit {
            Some(v) => {
                sh.touch(hash);
                self.metrics.hits.inc();
                Some(v)
            }
            None => {
                self.metrics.misses.inc();
                None
            }
        }
    }

    /// The invalidation epoch of `(table, code)`'s shard. Snapshot this
    /// *before* fetching from the engine and pass it to [`insert_if`]:
    /// if an invalidation lands in between, the insert is refused and
    /// the stale fetch is not cached.
    ///
    /// [`insert_if`]: CuboidCache::insert_if
    pub fn epoch(&self, table: &str, code: u64) -> u64 {
        let hash = key_hash(table, code);
        self.shard_of(hash).lock().unwrap().epoch
    }

    /// Insert unless the shard's invalidation epoch moved past `epoch`.
    /// Returns whether the entry was installed.
    pub fn insert_if(&self, table: &str, code: u64, value: Option<Blob>, epoch: u64) -> bool {
        let hash = key_hash(table, code);
        let charged = value.as_ref().map(|b| b.len()).unwrap_or(NEG_ENTRY_BYTES);
        if charged > self.per_shard_bytes {
            return false; // larger than a whole shard: never cacheable
        }
        let mut sh = self.shard_of(hash).lock().unwrap();
        if sh.epoch != epoch {
            return false;
        }
        sh.remove(hash);
        while sh.bytes + charged > self.per_shard_bytes {
            let Some(victim) = sh.lru.values().next().copied() else { break };
            sh.remove(victim);
            self.metrics.evictions.inc();
        }
        let tick = sh.next_tick;
        sh.next_tick += 1;
        sh.bytes += charged;
        sh.lru.insert(tick, hash);
        sh.map.insert(
            hash,
            Entry { table: table.to_string(), code, value, charged, tick },
        );
        self.metrics.inserts.inc();
        true
    }

    /// Unconditional insert (prewarming, tests).
    pub fn insert(&self, table: &str, code: u64, value: Option<Blob>) {
        let epoch = self.epoch(table, code);
        self.insert_if(table, code, value, epoch);
    }

    /// Drop `(table, code)` and bump the shard's invalidation epoch so
    /// in-flight reads cannot re-install a stale value.
    pub fn invalidate(&self, table: &str, code: u64) {
        let hash = key_hash(table, code);
        let mut sh = self.shard_of(hash).lock().unwrap();
        sh.epoch += 1;
        let held = sh
            .map
            .get(&hash)
            .map_or(false, |e| e.table == table && e.code == code);
        if held {
            sh.remove(hash);
        }
        self.metrics.invalidations.inc();
    }

    /// Drop everything (bench cold-start; bumps every shard's epoch).
    pub fn clear(&self) {
        for sh in &self.shards {
            let mut sh = sh.lock().unwrap();
            sh.map.clear();
            sh.lru.clear();
            sh.bytes = 0;
            sh.epoch += 1;
        }
    }

    /// Aggregate snapshot across shards.
    pub fn status(&self) -> CacheStatus {
        let mut entries = 0u64;
        let mut bytes = 0u64;
        for sh in &self.shards {
            let sh = sh.lock().unwrap();
            entries += sh.map.len() as u64;
            bytes += sh.bytes as u64;
        }
        CacheStatus {
            entries,
            bytes,
            capacity_bytes: self.capacity_bytes as u64,
            shards: self.shards.len() as u64,
            hits: self.metrics.hits.get(),
            misses: self.metrics.misses.get(),
            inserts: self.metrics.inserts.get(),
            evictions: self.metrics.evictions.get(),
            invalidations: self.metrics.invalidations.get(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn blob(n: usize, fill: u8) -> Blob {
        Arc::new(vec![fill; n])
    }

    #[test]
    fn hit_miss_and_negative_entries() {
        let c = CuboidCache::new(CacheConfig::default());
        assert_eq!(c.get("t/cub/0/0", 5), None);
        c.insert("t/cub/0/0", 5, Some(blob(16, 1)));
        c.insert("t/cub/0/0", 6, None); // known-absent
        assert_eq!(**c.get("t/cub/0/0", 5).unwrap().unwrap(), vec![1u8; 16]);
        assert_eq!(c.get("t/cub/0/0", 6), Some(None), "negative hit");
        let st = c.status();
        assert_eq!(st.hits, 2);
        assert_eq!(st.misses, 1);
        assert_eq!(st.entries, 2);
        assert!(st.hit_rate() > 0.6);
    }

    #[test]
    fn tables_are_separate_key_spaces() {
        let c = CuboidCache::new(CacheConfig::default());
        c.insert("a/cub/0/0", 1, Some(blob(4, 7)));
        assert_eq!(c.get("b/cub/0/0", 1), None);
        assert!(c.get("a/cub/0/0", 1).is_some());
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        // One shard, tiny budget: 4 x 100-byte entries fit, 5th evicts
        // the least recently used.
        let c = CuboidCache::new(CacheConfig { shards: 1, capacity_bytes: 400 });
        for code in 0..4u64 {
            c.insert("t", code, Some(blob(100, code as u8)));
        }
        // Touch 0 so 1 becomes the LRU victim.
        assert!(c.get("t", 0).is_some());
        c.insert("t", 9, Some(blob(100, 9)));
        assert!(c.get("t", 0).is_some(), "recently used survived");
        assert_eq!(c.get("t", 1), None, "LRU victim evicted");
        let st = c.status();
        assert!(st.bytes <= 400);
        assert!(st.evictions >= 1);
    }

    #[test]
    fn oversized_blob_never_cached() {
        let c = CuboidCache::new(CacheConfig { shards: 1, capacity_bytes: 64 });
        c.insert("t", 0, Some(blob(1000, 1)));
        assert_eq!(c.get("t", 0), None);
        assert_eq!(c.status().entries, 0);
    }

    #[test]
    fn invalidate_removes_and_fences_racing_insert() {
        let c = CuboidCache::new(CacheConfig::default());
        c.insert("t", 3, Some(blob(8, 1)));
        // A reader snapshots the epoch, then a writer invalidates (as
        // write_cuboids does after the engine write), then the reader
        // tries to install what it fetched before the write.
        let epoch = c.epoch("t", 3);
        c.invalidate("t", 3);
        assert_eq!(c.get("t", 3), None, "invalidated entry gone");
        assert!(!c.insert_if("t", 3, Some(blob(8, 2)), epoch), "stale insert fenced");
        assert_eq!(c.get("t", 3), None, "no stale value installed");
        // A fresh read (post-invalidation epoch) caches fine.
        let epoch = c.epoch("t", 3);
        assert!(c.insert_if("t", 3, Some(blob(8, 3)), epoch));
        assert_eq!(**c.get("t", 3).unwrap().unwrap(), vec![3u8; 8]);
    }

    #[test]
    fn clear_empties_all_shards() {
        let c = CuboidCache::new(CacheConfig { shards: 4, capacity_bytes: 1 << 16 });
        for code in 0..64u64 {
            c.insert("t", code, Some(blob(16, 1)));
        }
        assert!(c.status().entries > 0);
        c.clear();
        let st = c.status();
        assert_eq!(st.entries, 0);
        assert_eq!(st.bytes, 0);
    }

    #[test]
    fn concurrent_readers_and_invalidators_stay_consistent() {
        let c = Arc::new(CuboidCache::new(CacheConfig { shards: 4, capacity_bytes: 1 << 20 }));
        std::thread::scope(|s| {
            for w in 0..4u64 {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for i in 0..500u64 {
                        let code = (w * 131 + i) % 64;
                        match i % 3 {
                            0 => {
                                let e = c.epoch("t", code);
                                c.insert_if("t", code, Some(Arc::new(vec![w as u8; 32])), e);
                            }
                            1 => {
                                let _ = c.get("t", code);
                            }
                            _ => c.invalidate("t", code),
                        }
                    }
                });
            }
        });
        // Internal accounting intact: bytes matches live entries.
        let st = c.status();
        assert_eq!(st.bytes, st.entries * 32);
    }
}
