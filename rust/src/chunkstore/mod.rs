//! The cuboid store: dense, Morton-keyed, gzip-compressed cuboids over a
//! [`StorageEngine`] — the paper's basic storage structure (§3, §3.2).
//!
//! * Keys are 3-d Morton codes of cuboid-grid coordinates (4-d when the
//!   dataset has a time dimension).
//! * Cuboids are allocated lazily: regions never written occupy no
//!   storage and read back as zeros (§3.2 "we allocate cuboids lazily").
//! * Values are framed as `[codec tag][raw len][payload]`; image data uses
//!   gzip (compresses <10%), annotation labels compress dramatically, and
//!   an RLE codec is provided for the ablation bench (§3.2).
//! * Reads over sorted code sets are coalesced into maximal contiguous
//!   Morton runs and served by `get_run` — one streaming I/O per run.
//! * An optional sharded LRU [`CuboidCache`] sits in front of the
//!   engine: consulted per code on read, populated on miss (with the
//!   epoch fence of [`cache`]'s invalidation protocol), and invalidated
//!   by every write.

pub mod cache;

pub use cache::{CacheConfig, CacheMetrics, CacheStatus, CuboidCache};

use std::sync::{Arc, OnceLock};

use crate::array::{DenseVolume, VoxelScalar};
use crate::core::{Dataset, Project, Vec3};
use crate::morton;
use crate::obs::heat::HeatTracker;
use crate::storage::{Blob, Engine};
use crate::util::{codec, gzip};
use crate::{Error, Result};

/// Value framing codecs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    Raw,
    Gzip(u32),
    /// Run-length (32-bit words only — annotation labels).
    Rle32,
}

impl Codec {
    fn tag(self) -> u8 {
        match self {
            Codec::Raw => 0,
            Codec::Gzip(_) => 1,
            Codec::Rle32 => 2,
        }
    }
}

/// Handle to one project's cuboid space on one engine.
pub struct CuboidStore {
    pub dataset: Arc<Dataset>,
    pub project: Arc<Project>,
    engine: Engine,
    codec: Codec,
    cache: Option<Arc<CuboidCache>>,
    /// Workload heat map (DESIGN.md §11): every keyed read/write below
    /// — cache hits included — is recorded here when the cluster
    /// attaches a tracker. Set once; reads are lock-free.
    heat: OnceLock<Arc<HeatTracker>>,
}

impl CuboidStore {
    pub fn new(dataset: Arc<Dataset>, project: Arc<Project>, engine: Engine) -> Self {
        let codec =
            if project.gzip_level == 0 { Codec::Raw } else { Codec::Gzip(project.gzip_level) };
        CuboidStore { dataset, project, engine, codec, cache: None, heat: OnceLock::new() }
    }

    /// Override the value codec (ablation bench: gzip vs RLE vs raw).
    pub fn with_codec(mut self, codec: Codec) -> Self {
        self.codec = codec;
        self
    }

    /// Attach a cuboid cache in front of the engine.
    pub fn with_cache(mut self, cache: Arc<CuboidCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// The attached cuboid cache, if any.
    pub fn cache(&self) -> Option<&Arc<CuboidCache>> {
        self.cache.as_ref()
    }

    /// Attach the project's heat tracker. Idempotent: only the first
    /// attach wins (the cluster attaches exactly one per project).
    pub fn set_heat(&self, heat: Arc<HeatTracker>) {
        let _ = self.heat.set(heat);
    }

    /// The attached heat tracker, if any.
    pub fn heat(&self) -> Option<&Arc<HeatTracker>> {
        self.heat.get()
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Cuboid shape at `res`.
    pub fn cuboid_shape(&self, res: u32) -> Result<Vec3> {
        Ok(self.dataset.level(res)?.cuboid)
    }

    /// Serialize one cuboid.
    fn frame<T: VoxelScalar>(&self, vol: &DenseVolume<T>) -> Result<Vec<u8>> {
        let raw = vol.as_bytes();
        let mut e = codec::Enc::with_capacity(raw.len() / 4 + 16);
        match self.codec {
            Codec::Raw => {
                e.u8(Codec::Raw.tag()).varint(raw.len() as u64);
                let mut buf = e.finish();
                buf.extend_from_slice(raw);
                Ok(buf)
            }
            Codec::Gzip(level) => {
                let z = gzip::compress(raw, level)?;
                // Store raw when compression does not pay (high-entropy EM
                // data) — saves the inflate on read.
                if z.len() >= raw.len() {
                    e.u8(Codec::Raw.tag()).varint(raw.len() as u64);
                    let mut buf = e.finish();
                    buf.extend_from_slice(raw);
                    Ok(buf)
                } else {
                    e.u8(Codec::Gzip(level).tag()).varint(raw.len() as u64);
                    let mut buf = e.finish();
                    buf.extend_from_slice(&z);
                    Ok(buf)
                }
            }
            Codec::Rle32 => {
                if T::BYTES != 4 {
                    return Err(Error::Codec("rle32 requires 4-byte voxels".into()));
                }
                let words: Vec<u32> = raw
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let rle = gzip::rle32_encode(&words);
                e.u8(Codec::Rle32.tag()).varint(raw.len() as u64);
                let mut buf = e.finish();
                buf.extend_from_slice(&rle);
                Ok(buf)
            }
        }
    }

    /// Deserialize one cuboid of shape `shape`.
    fn unframe<T: VoxelScalar>(&self, shape: Vec3, value: &[u8]) -> Result<DenseVolume<T>> {
        let mut d = codec::Dec::new(value);
        let tag = d.u8()?;
        let raw_len = d.varint()? as usize;
        let payload = &value[value.len() - d.remaining()..];
        let raw = match tag {
            0 => payload.to_vec(),
            1 => gzip::decompress(payload, raw_len)?,
            2 => {
                let words = gzip::rle32_decode(payload, raw_len / 4)?;
                let mut raw = Vec::with_capacity(raw_len);
                for w in words {
                    raw.extend_from_slice(&w.to_le_bytes());
                }
                raw
            }
            _ => return Err(Error::Codec(format!("unknown cuboid codec {tag}"))),
        };
        DenseVolume::from_bytes(shape, &raw)
    }

    /// Read cuboids for sorted Morton `codes` at `(res, channel)`.
    /// Missing (never-written) cuboids come back as `None` — callers
    /// treat them as all-zero (lazy allocation). The cache (when
    /// attached) resolves what it can; the remainder is coalesced into
    /// maximal contiguous runs and fetched with single streaming reads,
    /// then installed in the cache under the epoch fence.
    pub fn read_cuboids<T: VoxelScalar>(
        &self,
        res: u32,
        channel: u16,
        codes: &[u64],
    ) -> Result<Vec<Option<DenseVolume<T>>>> {
        debug_assert!(codes.windows(2).all(|w| w[0] < w[1]), "codes must be sorted unique");
        let shape = self.cuboid_shape(res)?;
        let table = self.project.cuboid_table(res, channel);
        let mut sp = crate::obs::trace::span("cache", "lookup");
        sp.tag("cuboids", codes.len().to_string());

        // Resolve from the cache first; remember which slots are missing.
        let mut blobs: Vec<Option<Option<Blob>>> = vec![None; codes.len()];
        let mut missing_at: Vec<usize> = Vec::new();
        match &self.cache {
            Some(cache) => {
                for (i, &code) in codes.iter().enumerate() {
                    match cache.get(&table, code) {
                        Some(hit) => blobs[i] = Some(hit),
                        None => missing_at.push(i),
                    }
                }
            }
            None => missing_at.extend(0..codes.len()),
        }
        sp.tag("hits", (codes.len() - missing_at.len()).to_string());
        sp.tag("misses", missing_at.len().to_string());

        if !missing_at.is_empty() {
            let missing: Vec<u64> = missing_at.iter().map(|&i| codes[i]).collect();
            // Epoch snapshots BEFORE the engine fetch: an invalidation
            // racing this read fences the insert below.
            let epochs: Vec<u64> = match &self.cache {
                Some(cache) => missing.iter().map(|&c| cache.epoch(&table, c)).collect(),
                None => Vec::new(),
            };
            let mut j = 0usize; // cursor into `missing`
            for run in morton::coalesce_runs(&missing) {
                let got = self.engine.get_run(&table, run.start, run.len)?;
                let mut it = got.into_iter().peekable();
                for code in run.start..run.start + run.len {
                    let v = match it.peek() {
                        Some((k, _)) if *k == code => Some(it.next().unwrap().1),
                        _ => None,
                    };
                    if let Some(cache) = &self.cache {
                        cache.insert_if(&table, code, v.clone(), epochs[j]);
                    }
                    blobs[missing_at[j]] = Some(v);
                    j += 1;
                }
            }
        }

        if let Some(heat) = self.heat.get() {
            for (code, slot) in codes.iter().zip(&blobs) {
                let bytes = match slot {
                    Some(Some(v)) => v.len() as u64,
                    _ => 0,
                };
                heat.record_read(*code, bytes);
            }
        }

        blobs
            .into_iter()
            .map(|slot| match slot.expect("all slots resolved") {
                Some(v) => self.unframe(shape, &v).map(Some),
                None => Ok(None),
            })
            .collect()
    }

    /// Read a single cuboid (cache-aware).
    pub fn read_cuboid<T: VoxelScalar>(
        &self,
        res: u32,
        channel: u16,
        code: u64,
    ) -> Result<Option<DenseVolume<T>>> {
        let shape = self.cuboid_shape(res)?;
        let table = self.project.cuboid_table(res, channel);
        let note = |blob: &Option<Blob>| {
            if let Some(heat) = self.heat.get() {
                heat.record_read(code, blob.as_ref().map_or(0, |v| v.len() as u64));
            }
        };
        if let Some(cache) = &self.cache {
            if let Some(hit) = cache.get(&table, code) {
                note(&hit);
                return match hit {
                    Some(v) => Ok(Some(self.unframe(shape, &v)?)),
                    None => Ok(None),
                };
            }
            let epoch = cache.epoch(&table, code);
            let v = self.engine.get(&table, code)?;
            cache.insert_if(&table, code, v.clone(), epoch);
            note(&v);
            return match v {
                Some(v) => Ok(Some(self.unframe(shape, &v)?)),
                None => Ok(None),
            };
        }
        let v = self.engine.get(&table, code)?;
        note(&v);
        match v {
            Some(v) => Ok(Some(self.unframe(shape, &v)?)),
            None => Ok(None),
        }
    }

    /// Write cuboids as one batch. Volumes are borrowed — the write
    /// engine's workers hand over views of freshly merged cuboids without
    /// cloning them. All-zero cuboids are *deleted* (one `delete_batch`)
    /// rather than stored (lazy allocation invariant). Every written code
    /// is invalidated in the cache *after* the engine write, so later
    /// reads refetch through the engine (and its WAL overlay, when
    /// present).
    pub fn write_cuboids<T: VoxelScalar>(
        &self,
        res: u32,
        channel: u16,
        items: &[(u64, &DenseVolume<T>)],
    ) -> Result<()> {
        if self.project.readonly {
            return Err(Error::BadRequest(format!("project '{}' is readonly", self.project.token)));
        }
        let table = self.project.cuboid_table(res, channel);
        let mut batch = Vec::with_capacity(items.len());
        let mut dead: Vec<u64> = Vec::new();
        for (code, vol) in items {
            if vol.all_zero() {
                dead.push(*code);
            } else {
                batch.push((*code, self.frame(*vol)?));
            }
        }
        if !dead.is_empty() {
            self.engine.delete_batch(&table, &dead)?;
        }
        if !batch.is_empty() {
            self.engine.put_batch(&table, &batch)?;
        }
        if let Some(heat) = self.heat.get() {
            for (code, bytes) in &batch {
                heat.record_write(*code, bytes.len() as u64);
            }
            for code in &dead {
                heat.record_write(*code, 0);
            }
        }
        if let Some(cache) = &self.cache {
            for (code, _) in items {
                cache.invalidate(&table, *code);
            }
        }
        Ok(())
    }

    /// Write a single cuboid (borrowed; no volume clone).
    pub fn write_cuboid<T: VoxelScalar>(
        &self,
        res: u32,
        channel: u16,
        code: u64,
        vol: &DenseVolume<T>,
    ) -> Result<()> {
        self.write_cuboids(res, channel, &[(code, vol)])
    }

    /// Morton codes of every stored cuboid at `(res, channel)`, ascending.
    pub fn stored_codes(&self, res: u32, channel: u16) -> Result<Vec<u64>> {
        self.engine.keys(&self.project.cuboid_table(res, channel))
    }

    /// Stored (compressed) size of one cuboid in bytes, if present.
    pub fn stored_size(&self, res: u32, channel: u16, code: u64) -> Result<Option<usize>> {
        Ok(self
            .engine
            .get(&self.project.cuboid_table(res, channel), code)?
            .map(|v| v.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::DatasetBuilder;
    use crate::storage::MemStore;
    use crate::util::Rng;

    fn store(codec: Codec) -> CuboidStore {
        let ds = Arc::new(DatasetBuilder::new("t", [512, 512, 64]).levels(3).build());
        let pr = Arc::new(Project::annotation("ann", "t"));
        CuboidStore::new(ds, pr, Arc::new(MemStore::new())).with_codec(codec)
    }

    fn random_cuboid(rng: &mut Rng, shape: Vec3, card: u32) -> DenseVolume<u32> {
        let n = (shape[0] * shape[1] * shape[2]) as usize;
        DenseVolume::from_vec(shape, (0..n).map(|_| rng.below(card as u64) as u32).collect())
            .unwrap()
    }

    #[test]
    fn roundtrip_all_codecs() {
        for codec in [Codec::Raw, Codec::Gzip(6), Codec::Rle32] {
            let s = store(codec);
            let shape = s.cuboid_shape(0).unwrap();
            let mut rng = Rng::new(5);
            let vol = random_cuboid(&mut rng, shape, 4);
            s.write_cuboid(0, 0, 42, &vol).unwrap();
            let got = s.read_cuboid::<u32>(0, 0, 42).unwrap().unwrap();
            assert_eq!(got, vol, "codec {codec:?}");
        }
    }

    #[test]
    fn lazy_allocation_missing_reads_none() {
        let s = store(Codec::Gzip(6));
        assert!(s.read_cuboid::<u32>(0, 0, 7).unwrap().is_none());
        // Writing all-zero stores nothing.
        let shape = s.cuboid_shape(0).unwrap();
        s.write_cuboid(0, 0, 7, &DenseVolume::<u32>::zeros(shape)).unwrap();
        assert!(s.read_cuboid::<u32>(0, 0, 7).unwrap().is_none());
        assert_eq!(s.stored_codes(0, 0).unwrap(), Vec::<u64>::new());
    }

    #[test]
    fn overwrite_with_zeros_deletes() {
        let s = store(Codec::Gzip(6));
        let shape = s.cuboid_shape(0).unwrap();
        let mut v = DenseVolume::<u32>::zeros(shape);
        v.set([0, 0, 0], 9);
        s.write_cuboid(0, 0, 3, &v).unwrap();
        assert!(s.read_cuboid::<u32>(0, 0, 3).unwrap().is_some());
        s.write_cuboid(0, 0, 3, &DenseVolume::<u32>::zeros(shape)).unwrap();
        assert!(s.read_cuboid::<u32>(0, 0, 3).unwrap().is_none());
    }

    #[test]
    fn batch_read_with_gaps_preserves_positions() {
        let s = store(Codec::Gzip(1));
        let shape = s.cuboid_shape(1).unwrap();
        let mut rng = Rng::new(9);
        let a = random_cuboid(&mut rng, shape, 3);
        let b = random_cuboid(&mut rng, shape, 3);
        s.write_cuboids(1, 0, &[(10, &a), (12, &b)]).unwrap();
        let got = s.read_cuboids::<u32>(1, 0, &[9, 10, 11, 12, 13]).unwrap();
        assert!(got[0].is_none());
        assert_eq!(got[1].as_ref().unwrap(), &a);
        assert!(got[2].is_none());
        assert_eq!(got[3].as_ref().unwrap(), &b);
        assert!(got[4].is_none());
    }

    #[test]
    fn annotation_labels_compress_hard() {
        let s = store(Codec::Gzip(6));
        let shape = s.cuboid_shape(0).unwrap();
        let mut vol = DenseVolume::<u32>::zeros(shape);
        vol.fill_box(crate::core::Box3::new([0, 0, 0], [64, 64, 8]), 1234);
        s.write_cuboid(0, 0, 0, &vol).unwrap();
        let stored = s.stored_size(0, 0, 0).unwrap().unwrap();
        let raw = vol.as_bytes().len();
        assert!(stored * 40 < raw, "stored {stored} vs raw {raw}");
    }

    #[test]
    fn incompressible_image_stored_raw() {
        // The gzip frame falls back to raw when compression does not pay,
        // so reads skip the inflate.
        let ds = Arc::new(DatasetBuilder::new("t", [512, 512, 64]).levels(1).build());
        let pr = Arc::new(Project::image("img", "t"));
        let s = CuboidStore::new(ds, pr, Arc::new(MemStore::new()));
        let shape = s.cuboid_shape(0).unwrap();
        let n = (shape[0] * shape[1] * shape[2]) as usize;
        let mut rng = Rng::new(3);
        let vol =
            DenseVolume::<u8>::from_vec(shape, (0..n).map(|_| rng.next_u32() as u8).collect())
                .unwrap();
        s.write_cuboid(0, 0, 5, &vol).unwrap();
        let stored = s.stored_size(0, 0, 5).unwrap().unwrap();
        assert!(stored <= n + 16, "raw fallback expected, got {stored} for {n}");
        assert_eq!(s.read_cuboid::<u8>(0, 0, 5).unwrap().unwrap(), vol);
    }

    #[test]
    fn cached_store_serves_hits_and_negatives_without_engine() {
        use crate::storage::StorageEngine;
        let ds = Arc::new(DatasetBuilder::new("t", [512, 512, 64]).levels(1).build());
        let pr = Arc::new(Project::annotation("ann", "t"));
        let mem = Arc::new(MemStore::new());
        let cache = Arc::new(CuboidCache::new(CacheConfig::default()));
        let s = CuboidStore::new(ds, pr, Arc::clone(&mem) as Engine)
            .with_cache(Arc::clone(&cache));
        let shape = s.cuboid_shape(0).unwrap();
        let mut rng = Rng::new(11);
        let vol = random_cuboid(&mut rng, shape, 5);
        s.write_cuboid(0, 0, 4, &vol).unwrap();

        // Cold read populates; codes 3 and 5 are absent → negative entries.
        let got = s.read_cuboids::<u32>(0, 0, &[3, 4, 5]).unwrap();
        assert!(got[0].is_none() && got[2].is_none());
        assert_eq!(got[1].as_ref().unwrap(), &vol);
        let engine_reads = mem.stats().snapshot();

        // Warm read: engine untouched, all three served by the cache.
        let again = s.read_cuboids::<u32>(0, 0, &[3, 4, 5]).unwrap();
        assert_eq!(again[1].as_ref().unwrap(), &vol);
        assert!(again[0].is_none() && again[2].is_none());
        assert_eq!(mem.stats().snapshot(), engine_reads, "warm read must not touch engine");
        assert!(cache.status().hits >= 3);
    }

    #[test]
    fn write_invalidates_cache() {
        let ds = Arc::new(DatasetBuilder::new("t", [512, 512, 64]).levels(1).build());
        let pr = Arc::new(Project::annotation("ann", "t"));
        let cache = Arc::new(CuboidCache::new(CacheConfig::default()));
        let s = CuboidStore::new(ds, pr, Arc::new(MemStore::new()))
            .with_cache(Arc::clone(&cache));
        let shape = s.cuboid_shape(0).unwrap();
        let mut rng = Rng::new(13);
        let v1 = random_cuboid(&mut rng, shape, 3);
        let v2 = random_cuboid(&mut rng, shape, 7);
        s.write_cuboid(0, 0, 8, &v1).unwrap();
        assert_eq!(s.read_cuboid::<u32>(0, 0, 8).unwrap().unwrap(), v1);
        s.write_cuboid(0, 0, 8, &v2).unwrap();
        assert_eq!(
            s.read_cuboid::<u32>(0, 0, 8).unwrap().unwrap(),
            v2,
            "stale cache entry served after overwrite"
        );
        // Deleting (all-zero write) invalidates the positive entry too.
        s.write_cuboid(0, 0, 8, &DenseVolume::<u32>::zeros(shape)).unwrap();
        assert!(s.read_cuboid::<u32>(0, 0, 8).unwrap().is_none());
        assert!(cache.status().invalidations >= 3);
    }

    #[test]
    fn readonly_rejects_writes() {
        let ds = Arc::new(DatasetBuilder::new("t", [128, 128, 16]).levels(1).build());
        let pr = Arc::new(Project::image("img", "t").readonly());
        let s = CuboidStore::new(ds, pr, Arc::new(MemStore::new()));
        let shape = s.cuboid_shape(0).unwrap();
        let err = s.write_cuboid(0, 0, 0, &DenseVolume::<u8>::zeros(shape));
        assert!(err.is_err());
    }
}
