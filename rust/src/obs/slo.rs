//! Latency SLOs per route class: attainment and error-budget burn,
//! computed from the transport's per-route latency histograms.
//!
//! Routes fall into three classes with distinct objectives:
//!
//! * **interactive** — cutouts, planes, tiles, RAMON reads: the
//!   visualization path, where a human is waiting;
//! * **bulk** — volume writes, image ingest, job submission, WAL
//!   drains: throughput-bound, latency-tolerant;
//! * **status** — info/status/metrics polls: cheap and frequent.
//!
//! Each class declares a latency threshold and an objective (the
//! fraction of requests that must finish under the threshold).
//! Thresholds sit exactly on log2 histogram bucket edges
//! ([`crate::metrics::HistogramSnapshot::bucket_edge`]), so attainment
//! is computed exactly from bucket counts — no interpolation.
//!
//! **Error-budget burn** is the ratio of observed over-threshold
//! requests to the number the objective allows: burn `< 1000` milli
//! means budget remains, `≥ 1000` means the objective is currently
//! missed (and [`evaluate`] emits a structured-log warning). The
//! families render as `ocpd_slo_*` on `GET /metrics/`.

use std::sync::Arc;

use crate::log_warn;
use crate::metrics::Histogram;

/// The three route classes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteClass {
    Interactive,
    Bulk,
    Status,
}

impl RouteClass {
    pub fn name(self) -> &'static str {
        match self {
            RouteClass::Interactive => "interactive",
            RouteClass::Bulk => "bulk",
            RouteClass::Status => "status",
        }
    }
}

/// A latency objective for one route class.
#[derive(Clone, Copy, Debug)]
pub struct Objective {
    pub class: RouteClass,
    /// Latency threshold, µs. Sits on a log2 bucket edge (`2^k − 1`) so
    /// bucket counts split exactly at it.
    pub threshold_us: u64,
    /// Required under-threshold fraction, in milli (990 = 99.0%).
    pub objective_milli: u64,
}

/// The declared objectives: interactive p99 < ~131 ms, bulk p99 <
/// ~4.2 s, status p99.5 < ~33 ms.
pub const OBJECTIVES: [Objective; 3] = [
    Objective {
        class: RouteClass::Interactive,
        threshold_us: (1 << 17) - 1, // 131071 µs ≈ 131 ms
        objective_milli: 990,
    },
    Objective {
        class: RouteClass::Bulk,
        threshold_us: (1 << 22) - 1, // ≈ 4.2 s
        objective_milli: 990,
    },
    Objective {
        class: RouteClass::Status,
        threshold_us: (1 << 15) - 1, // 32767 µs ≈ 33 ms
        objective_milli: 995,
    },
];

/// Which class a route (by its router name) belongs to.
pub fn class_of_route(route: &str) -> RouteClass {
    match route {
        // Reads a human is waiting on.
        "cutout" | "plane" | "tile" | "objects-query" | "region" | "voxels"
        | "boundingbox" | "object-cutout" | "object-cutout-box" | "metadata" => {
            RouteClass::Interactive
        }
        // Ingest and batch-work submission.
        "ramon-put" | "image-put" | "annotation-put" | "jobs-propagate" | "jobs-synapse"
        | "jobs-ingest" | "wal-flush" | "wal-flush-one" | "cluster-failover"
        | "write-workers" | "shards-split" => RouteClass::Bulk,
        // Everything else polls state.
        _ => RouteClass::Status,
    }
}

/// Attainment and burn for one class.
#[derive(Clone, Copy, Debug)]
pub struct ClassReport {
    pub class: RouteClass,
    pub threshold_us: u64,
    pub objective_milli: u64,
    /// Requests observed in this class.
    pub total: u64,
    /// Requests that finished under the threshold.
    pub within: u64,
    /// `within / total`, milli. 1000 when no traffic.
    pub attainment_milli: u64,
    /// Error-budget burn, milli: observed misses over allowed misses.
    /// 0 when no traffic; ≥ 1000 means the objective is missed.
    pub burn_milli: u64,
}

/// The full SLO evaluation across classes.
#[derive(Clone, Debug)]
pub struct SloReport {
    pub classes: Vec<ClassReport>,
}

impl SloReport {
    /// Human-readable rendering (the `GET /slo/status/` body).
    pub fn render_text(&self) -> String {
        let mut out = String::from("slo:\n");
        for c in &self.classes {
            out.push_str(&format!(
                "  {}: threshold={}us objective={}.{}% total={} within={} \
                 attainment={}.{}% budget_burn={}.{:03}x\n",
                c.class.name(),
                c.threshold_us,
                c.objective_milli / 10,
                c.objective_milli % 10,
                c.total,
                c.within,
                c.attainment_milli / 10,
                c.attainment_milli % 10,
                c.burn_milli / 1000,
                c.burn_milli % 1000,
            ));
        }
        out
    }
}

/// How many of `h`'s recorded values are `≤ threshold_us`. Exact when
/// the threshold is a bucket upper edge, which [`OBJECTIVES`] are.
fn count_within(h: &Histogram, threshold_us: u64) -> (u64, u64) {
    let snap = h.snapshot();
    let mut within = 0u64;
    for (i, &c) in snap.buckets.iter().enumerate() {
        if c == 0 {
            continue;
        }
        if crate::metrics::HistogramSnapshot::bucket_edge(i) <= threshold_us {
            within += c;
        }
    }
    (within, snap.count)
}

/// Evaluate the objectives against the transport's per-route
/// histograms. Emits a `log_warn!` per class whose error budget is
/// exhausted (burn ≥ 1000 milli).
pub fn evaluate(route_hists: &[(&'static str, Arc<Histogram>)]) -> SloReport {
    let mut classes = Vec::with_capacity(OBJECTIVES.len());
    for obj in OBJECTIVES {
        let mut total = 0u64;
        let mut within = 0u64;
        for (route, hist) in route_hists {
            if class_of_route(route) != obj.class {
                continue;
            }
            let (w, t) = count_within(hist, obj.threshold_us);
            within += w;
            total += t;
        }
        let attainment_milli =
            if total == 0 { 1000 } else { within.saturating_mul(1000) / total };
        let burn_milli = if total == 0 {
            0
        } else {
            let missed = (total - within) as f64;
            // Allowed misses under the objective; floor at a fraction of
            // one request so low-traffic classes still report burn.
            let allowed =
                (total as f64 * (1000 - obj.objective_milli) as f64 / 1000.0).max(1e-9);
            ((missed / allowed) * 1000.0).round().min(u64::MAX as f64) as u64
        };
        if burn_milli >= 1000 {
            log_warn!(
                target: "slo",
                "error budget exhausted class={} attainment_milli={} burn_milli={} total={}",
                obj.class.name(),
                attainment_milli,
                burn_milli,
                total
            );
        }
        classes.push(ClassReport {
            class: obj.class,
            threshold_us: obj.threshold_us,
            objective_milli: obj.objective_milli,
            total,
            within,
            attainment_milli,
            burn_milli,
        });
    }
    SloReport { classes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn hist_with(values_us: &[u64]) -> Arc<Histogram> {
        let h = Histogram::new();
        for &v in values_us {
            h.record(Duration::from_micros(v));
        }
        Arc::new(h)
    }

    #[test]
    fn route_classes_cover_the_route_table() {
        assert_eq!(class_of_route("cutout"), RouteClass::Interactive);
        assert_eq!(class_of_route("tile"), RouteClass::Interactive);
        assert_eq!(class_of_route("image-put"), RouteClass::Bulk);
        assert_eq!(class_of_route("jobs-ingest"), RouteClass::Bulk);
        assert_eq!(class_of_route("jobs-status"), RouteClass::Status);
        assert_eq!(class_of_route("metrics"), RouteClass::Status);
        assert_eq!(class_of_route("never-heard-of-it"), RouteClass::Status);
    }

    #[test]
    fn attainment_counts_under_threshold_exactly() {
        // 9 fast (1 ms) + 1 slow (1 s) interactive requests: 90.0%.
        let mut fast: Vec<u64> = vec![1_000; 9];
        fast.push(1_000_000);
        let report = evaluate(&[("cutout", hist_with(&fast))]);
        let c = report
            .classes
            .iter()
            .find(|c| c.class == RouteClass::Interactive)
            .unwrap();
        assert_eq!(c.total, 10);
        assert_eq!(c.within, 9);
        assert_eq!(c.attainment_milli, 900);
        // Objective allows 1% of 10 = 0.1 requests; 1 miss burns 10x.
        assert_eq!(c.burn_milli, 10_000);
    }

    #[test]
    fn perfect_traffic_burns_nothing() {
        let report = evaluate(&[("tile", hist_with(&[500, 900, 2_000]))]);
        let c = report
            .classes
            .iter()
            .find(|c| c.class == RouteClass::Interactive)
            .unwrap();
        assert_eq!(c.attainment_milli, 1000);
        assert_eq!(c.burn_milli, 0);
    }

    #[test]
    fn no_traffic_reports_full_attainment() {
        let report = evaluate(&[]);
        for c in &report.classes {
            assert_eq!(c.attainment_milli, 1000);
            assert_eq!(c.burn_milli, 0);
            assert_eq!(c.total, 0);
        }
    }

    #[test]
    fn classes_do_not_bleed_into_each_other() {
        // A glacial bulk ingest must not hurt interactive attainment.
        let report = evaluate(&[
            ("cutout", hist_with(&[1_000, 2_000])),
            ("image-put", hist_with(&[10_000_000])),
        ]);
        let inter = report
            .classes
            .iter()
            .find(|c| c.class == RouteClass::Interactive)
            .unwrap();
        let bulk =
            report.classes.iter().find(|c| c.class == RouteClass::Bulk).unwrap();
        assert_eq!(inter.attainment_milli, 1000);
        assert_eq!(bulk.within, 0);
        assert!(bulk.burn_milli >= 1000);
    }
}
