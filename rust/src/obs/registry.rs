//! The unified metrics registry: one Prometheus-text-format exposition
//! (`GET /metrics/`) over every per-subsystem metrics struct.
//!
//! The subsystems keep their existing structs (`ReadMetrics`,
//! `WriteMetrics`, `CacheMetrics`, `JobMetrics`, `WalMetrics`,
//! `HttpMetrics`) and their JSON/text status routes; the registry adds
//! a pull layer on top. Each subsystem registers a keyed **collector**
//! — a closure capturing its `Arc`'d metrics — and a scrape runs every
//! collector, groups the emitted samples into families, and renders
//! Prometheus text format (version 0.0.4): one `# HELP`/`# TYPE` pair
//! per family, counters and gauges as plain series, histograms as
//! cumulative `_bucket{le=...}` series plus `_sum` and `_count`.
//!
//! Collectors are keyed so re-registering (a project re-created in
//! tests, a server restarted on the same cluster) replaces rather than
//! duplicates.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::Mutex;

use crate::metrics::HistogramSnapshot;

/// Prometheus metric families.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    fn as_str(&self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

/// A sample's value: a scalar (counter/gauge) or a full histogram
/// snapshot.
#[derive(Clone, Debug)]
pub enum Value {
    Num(u64),
    Hist(HistogramSnapshot),
}

/// One emitted sample: family name + kind + labels + value.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: &'static str,
    pub help: &'static str,
    pub kind: MetricKind,
    pub labels: Vec<(&'static str, String)>,
    pub value: Value,
}

impl Sample {
    pub fn counter(name: &'static str, help: &'static str, v: u64) -> Sample {
        Sample { name, help, kind: MetricKind::Counter, labels: Vec::new(), value: Value::Num(v) }
    }

    pub fn gauge(name: &'static str, help: &'static str, v: u64) -> Sample {
        Sample { name, help, kind: MetricKind::Gauge, labels: Vec::new(), value: Value::Num(v) }
    }

    pub fn histogram(name: &'static str, help: &'static str, s: HistogramSnapshot) -> Sample {
        Sample {
            name,
            help,
            kind: MetricKind::Histogram,
            labels: Vec::new(),
            value: Value::Hist(s),
        }
    }

    /// Attach a label (builder form).
    pub fn label(mut self, key: &'static str, value: impl Into<String>) -> Sample {
        self.labels.push((key, value.into()));
        self
    }
}

type Collector = Box<dyn Fn(&mut Vec<Sample>) + Send + Sync>;

/// The per-cluster registry. Cheap to scrape: collectors read atomics.
#[derive(Default)]
pub struct MetricsRegistry {
    collectors: Mutex<BTreeMap<String, Collector>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) the collector under `key`. Keys name the
    /// source ("project/mytoken", "http", "jobs") so registration is
    /// idempotent.
    pub fn register(
        &self,
        key: impl Into<String>,
        collector: impl Fn(&mut Vec<Sample>) + Send + Sync + 'static,
    ) {
        self.collectors.lock().unwrap().insert(key.into(), Box::new(collector));
    }

    /// Remove the collector under `key` (a deleted project).
    pub fn unregister(&self, key: &str) {
        self.collectors.lock().unwrap().remove(key);
    }

    /// Run every collector and return the raw samples.
    pub fn gather(&self) -> Vec<Sample> {
        let mut out = Vec::new();
        for c in self.collectors.lock().unwrap().values() {
            c(&mut out);
        }
        out
    }

    /// Render the Prometheus text exposition (the `GET /metrics/` body).
    pub fn render(&self) -> String {
        let samples = self.gather();
        // Group into families, sorted by name for a stable exposition.
        let mut families: BTreeMap<&'static str, (&'static str, MetricKind, Vec<&Sample>)> =
            BTreeMap::new();
        for s in &samples {
            families.entry(s.name).or_insert((s.help, s.kind, Vec::new())).2.push(s);
        }
        let mut out = String::new();
        for (name, (help, kind, series)) in families {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} {}", kind.as_str());
            for s in series {
                match &s.value {
                    Value::Num(v) => {
                        let _ = writeln!(out, "{name}{} {v}", fmt_labels(&s.labels, None));
                    }
                    Value::Hist(h) => render_histogram(&mut out, name, &s.labels, h),
                }
            }
        }
        out
    }
}

/// `{k="v",...}` with an optional extra `le` pair; empty label sets
/// render as nothing.
fn fmt_labels(labels: &[(&'static str, String)], le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn render_histogram(
    out: &mut String,
    name: &'static str,
    labels: &[(&'static str, String)],
    h: &HistogramSnapshot,
) {
    let mut cum = 0u64;
    for (i, b) in h.buckets.iter().enumerate() {
        cum += b;
        // Skip interior empty buckets but always emit the first and the
        // tail so the cumulative series stays well-formed without 32
        // lines per histogram.
        if *b == 0 && i != 0 && i != 31 {
            continue;
        }
        let edge = HistogramSnapshot::bucket_edge(i).to_string();
        let _ = writeln!(out, "{name}_bucket{} {cum}", fmt_labels(labels, Some(&edge)));
    }
    let _ = writeln!(out, "{name}_bucket{} {}", fmt_labels(labels, Some("+Inf")), h.count);
    let _ = writeln!(out, "{name}_sum{} {}", fmt_labels(labels, None), h.sum);
    let _ = writeln!(out, "{name}_count{} {}", fmt_labels(labels, None), h.count);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Histogram;
    use std::time::Duration;

    #[test]
    fn render_counter_and_gauge() {
        let r = MetricsRegistry::new();
        r.register("a", |out| {
            out.push(Sample::counter("ocpd_reads_total", "Reads served.", 7).label("project", "t"));
            out.push(Sample::gauge("ocpd_depth", "Queue depth.", 3));
        });
        let text = r.render();
        assert!(text.contains("# HELP ocpd_reads_total Reads served."));
        assert!(text.contains("# TYPE ocpd_reads_total counter"));
        assert!(text.contains("ocpd_reads_total{project=\"t\"} 7"));
        assert!(text.contains("# TYPE ocpd_depth gauge"));
        assert!(text.contains("ocpd_depth 3"));
    }

    #[test]
    fn render_histogram_cumulative() {
        let h = Histogram::new();
        h.record(Duration::from_micros(1));
        h.record(Duration::from_micros(100));
        let snap = h.snapshot();
        let r = MetricsRegistry::new();
        r.register("h", move |out| {
            out.push(Sample::histogram("ocpd_lat_us", "Latency.", snap));
        });
        let text = r.render();
        assert!(text.contains("# TYPE ocpd_lat_us histogram"));
        assert!(text.contains("ocpd_lat_us_bucket{le=\"1\"} 1"));
        // Bucket 6 ([64,127]) holds the 100; cumulative = 2.
        assert!(text.contains("ocpd_lat_us_bucket{le=\"127\"} 2"), "{text}");
        assert!(text.contains("ocpd_lat_us_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("ocpd_lat_us_sum 101"));
        assert!(text.contains("ocpd_lat_us_count 2"));
    }

    #[test]
    fn register_is_idempotent_by_key() {
        let r = MetricsRegistry::new();
        r.register("k", |out| out.push(Sample::counter("ocpd_x_total", "X.", 1)));
        r.register("k", |out| out.push(Sample::counter("ocpd_x_total", "X.", 2)));
        let text = r.render();
        assert!(text.contains("ocpd_x_total 2"));
        assert_eq!(text.lines().filter(|l| l.starts_with("ocpd_x_total ")).count(), 1);
        r.unregister("k");
        assert!(r.render().is_empty());
    }
}
