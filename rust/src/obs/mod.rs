//! Observability: end-to-end request tracing, the unified metrics
//! registry, and structured logging.
//!
//! The paper's evaluation (§4) attributes every millisecond of a cutout
//! to a layer — index lookup, cuboid fetch, assembly. This module is
//! the reproduction's analogue, three cooperating pieces:
//!
//! * [`trace`] — a lightweight span tracer. The web tier opens a root
//!   span per request (honoring an inbound `X-Request-Id`, minting one
//!   otherwise, echoing it on the response); the cutout engine, the
//!   cuboid cache, the sharded fan-out workers, the WAL group commit,
//!   and job blocks open child spans tagged with their layer and
//!   shard/node. Completed traces land in bounded ring buffers with
//!   **tail-based retention**: traces slower than a threshold are always
//!   kept (the slow-request log), the rest are 1-in-N sampled.
//! * [`registry`] — a [`registry::MetricsRegistry`] that the six
//!   per-subsystem metrics structs register into, serving one
//!   Prometheus-text-format `GET /metrics/` exposition alongside the
//!   subsystem JSON/text routes.
//! * [`log`] — leveled `log_*!` macros (target, key=value payloads,
//!   request-id correlation from the active trace, `OCPD_LOG` filter)
//!   replacing raw `println!`/`eprintln!` for server-side events.

//! PR 8 adds the *workload* telemetry tier on top (DESIGN.md §11):
//!
//! * [`heat`] — per-project decaying access counters bucketed over the
//!   Morton key-space, aggregated per shard (the load signal a dynamic
//!   shard splitter needs);
//! * [`account`] — per-project/tenant resource ledgers (requests,
//!   bytes, worker-seconds) that quotas and fair scheduling will
//!   enforce against;
//! * [`slo`] — latency objectives per route class, with attainment and
//!   error-budget burn computed from the transport's per-route
//!   histograms.

pub mod account;
pub mod heat;
pub mod log;
pub mod registry;
pub mod slo;
pub mod trace;
