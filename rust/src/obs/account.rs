//! Per-project resource accounting: the tenant ledgers that multi-
//! tenant QoS (quotas, fair scheduling — ROADMAP item 2) will enforce
//! against.
//!
//! An [`Accountant`] holds one [`Ledger`] per project token. Feeds:
//!
//! * **request admission** — the web tier attributes every request
//!   whose first path segment is a live project token: request count
//!   plus body bytes in and response bytes out;
//! * **worker pools** — the cutout read and write engines record each
//!   worker's busy time (summed across the fan-out, not wall time), and
//!   the jobs engine records per-block execution time, so
//!   `worker-seconds` reflects what the pools actually spent per
//!   tenant;
//! * **cache residency** — the cluster reports each project's cuboid
//!   cache bytes held at scrape time (a gauge, not a counter).
//!
//! All counters are lock-free atomics; the ledger map takes a write
//! lock only when a new token first appears.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Monotonic per-project resource counters.
#[derive(Default)]
pub struct Ledger {
    requests: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    read_worker_us: AtomicU64,
    write_worker_us: AtomicU64,
    job_worker_us: AtomicU64,
}

impl Ledger {
    /// One admitted request with `bytes_in` of body and `bytes_out` of
    /// response payload.
    pub fn record_request(&self, bytes_in: u64, bytes_out: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(bytes_in, Ordering::Relaxed);
        self.bytes_out.fetch_add(bytes_out, Ordering::Relaxed);
    }

    /// Busy microseconds spent in the cutout read pool.
    pub fn add_read_worker_us(&self, us: u64) {
        self.read_worker_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Busy microseconds spent in the write pool.
    pub fn add_write_worker_us(&self, us: u64) {
        self.write_worker_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Busy microseconds spent executing job blocks.
    pub fn add_job_worker_us(&self, us: u64) {
        self.job_worker_us.fetch_add(us, Ordering::Relaxed);
    }

    /// A point-in-time copy of the counters.
    pub fn snapshot(&self) -> LedgerSnapshot {
        LedgerSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            read_worker_us: self.read_worker_us.load(Ordering::Relaxed),
            write_worker_us: self.write_worker_us.load(Ordering::Relaxed),
            job_worker_us: self.job_worker_us.load(Ordering::Relaxed),
        }
    }
}

/// Copied counter values of one [`Ledger`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LedgerSnapshot {
    pub requests: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub read_worker_us: u64,
    pub write_worker_us: u64,
    pub job_worker_us: u64,
}

/// The ledger map: one [`Ledger`] per project token, created on first
/// touch.
#[derive(Default)]
pub struct Accountant {
    ledgers: RwLock<HashMap<String, Arc<Ledger>>>,
}

impl Accountant {
    pub fn new() -> Self {
        Self::default()
    }

    /// The ledger for `token`, creating it on first use.
    pub fn ledger(&self, token: &str) -> Arc<Ledger> {
        if let Some(l) = self.ledgers.read().unwrap().get(token) {
            return Arc::clone(l);
        }
        let mut w = self.ledgers.write().unwrap();
        Arc::clone(w.entry(token.to_string()).or_default())
    }

    /// The ledger for `token` if one exists (read-only surfaces).
    pub fn get(&self, token: &str) -> Option<Arc<Ledger>> {
        self.ledgers.read().unwrap().get(token).cloned()
    }

    /// Drop `token`'s ledger (project deletion).
    pub fn remove(&self, token: &str) {
        self.ledgers.write().unwrap().remove(token);
    }

    /// All ledgers, token-sorted, snapshotted.
    pub fn snapshot(&self) -> Vec<(String, LedgerSnapshot)> {
        let mut out: Vec<(String, LedgerSnapshot)> = self
            .ledgers
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.snapshot()))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_counts_accumulate() {
        let a = Accountant::new();
        let l = a.ledger("img");
        l.record_request(100, 4096);
        l.record_request(0, 512);
        l.add_read_worker_us(250);
        l.add_write_worker_us(40);
        l.add_job_worker_us(9);
        let s = a.ledger("img").snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.bytes_in, 100);
        assert_eq!(s.bytes_out, 4608);
        assert_eq!(s.read_worker_us, 250);
        assert_eq!(s.write_worker_us, 40);
        assert_eq!(s.job_worker_us, 9);
    }

    #[test]
    fn ledgers_are_per_token_and_removable() {
        let a = Accountant::new();
        a.ledger("a").record_request(1, 1);
        a.ledger("b").record_request(2, 2);
        let snap = a.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0, "a");
        assert_eq!(snap[1].1.bytes_in, 2);
        a.remove("a");
        assert!(a.get("a").is_none());
        assert_eq!(a.snapshot().len(), 1);
    }

    #[test]
    fn same_token_shares_one_ledger() {
        let a = Accountant::new();
        let l1 = a.ledger("x");
        let l2 = a.ledger("x");
        l1.record_request(0, 0);
        l2.record_request(0, 0);
        assert_eq!(a.ledger("x").snapshot().requests, 2);
    }
}
