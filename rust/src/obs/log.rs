//! Structured, leveled logging for server-side events.
//!
//! The `log_error!` / `log_warn!` / `log_info!` / `log_debug!` macros
//! replace raw `println!`/`eprintln!` on the server path. Each record
//! carries a level, a target (the subsystem: "serve", "wal", "jobs",
//! ...), a message whose payload is `key=value` pairs by convention,
//! and — when the calling thread has an active trace — the request id
//! (`req=<id>`), correlating log lines with `/trace/*` output.
//!
//! Filtering follows the familiar env-logger shape via `OCPD_LOG`:
//! a bare level (`OCPD_LOG=debug`) sets the default, comma-separated
//! `target=level` pairs override per target (`OCPD_LOG=warn,wal=debug`).
//! The default is `info`. The filter parses once, so the per-call cost
//! of a suppressed record is one `OnceLock` read and a slice scan.
//!
//! Records go to stderr (stdout stays reserved for CLI data output) via
//! an explicit locked `writeln!` — the clippy gate that bans
//! `print!`/`eprintln!` in the library does not apply here because this
//! is the sanctioned sink.

use std::io::Write as _;
use std::sync::OnceLock;

/// Log severity, ordered: a filter at `Info` admits `Error`/`Warn`/
/// `Info` and suppresses `Debug`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }

    fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" | "trace" => Some(Level::Debug),
            _ => None,
        }
    }
}

/// Parsed `OCPD_LOG` filter: a default level plus per-target overrides.
struct Filter {
    default: Level,
    targets: Vec<(String, Level)>,
}

impl Filter {
    fn parse(spec: &str) -> Filter {
        let mut f = Filter { default: Level::Info, targets: Vec::new() };
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match part.split_once('=') {
                Some((target, level)) => {
                    if let Some(l) = Level::parse(level) {
                        f.targets.push((target.trim().to_string(), l));
                    }
                }
                None => {
                    if let Some(l) = Level::parse(part) {
                        f.default = l;
                    }
                }
            }
        }
        f
    }

    fn max_level(&self, target: &str) -> Level {
        self.targets
            .iter()
            .find(|(t, _)| t == target)
            .map(|(_, l)| *l)
            .unwrap_or(self.default)
    }
}

fn filter() -> &'static Filter {
    static FILTER: OnceLock<Filter> = OnceLock::new();
    FILTER.get_or_init(|| Filter::parse(&std::env::var("OCPD_LOG").unwrap_or_default()))
}

/// Whether a record at `level` for `target` would be emitted — the
/// macros check this before paying any formatting cost.
pub fn enabled(target: &str, level: Level) -> bool {
    level <= filter().max_level(target)
}

/// Emit one record. Called by the macros after an [`enabled`] check;
/// appends `req=<id>` when the calling thread has an active trace.
pub fn write(target: &str, level: Level, args: std::fmt::Arguments<'_>) {
    let req = crate::obs::trace::current_request_id();
    let mut err = std::io::stderr().lock();
    let _ = match req {
        Some(id) => writeln!(err, "[{} {}] {} req={}", level.as_str(), target, args, id),
        None => writeln!(err, "[{} {}] {}", level.as_str(), target, args),
    };
}

/// Log at [`Level::Error`]. `log_error!("msg {}", v)` targets "ocpd";
/// `log_error!(target: "wal", "msg")` names the subsystem.
#[macro_export]
macro_rules! log_error {
    (target: $target:expr, $($arg:tt)+) => {
        if $crate::obs::log::enabled($target, $crate::obs::log::Level::Error) {
            $crate::obs::log::write(
                $target,
                $crate::obs::log::Level::Error,
                format_args!($($arg)+),
            );
        }
    };
    ($($arg:tt)+) => { $crate::log_error!(target: "ocpd", $($arg)+) };
}

/// Log at [`Level::Warn`] (see [`log_error!`] for the forms).
#[macro_export]
macro_rules! log_warn {
    (target: $target:expr, $($arg:tt)+) => {
        if $crate::obs::log::enabled($target, $crate::obs::log::Level::Warn) {
            $crate::obs::log::write($target, $crate::obs::log::Level::Warn, format_args!($($arg)+));
        }
    };
    ($($arg:tt)+) => { $crate::log_warn!(target: "ocpd", $($arg)+) };
}

/// Log at [`Level::Info`] (see [`log_error!`] for the forms).
#[macro_export]
macro_rules! log_info {
    (target: $target:expr, $($arg:tt)+) => {
        if $crate::obs::log::enabled($target, $crate::obs::log::Level::Info) {
            $crate::obs::log::write($target, $crate::obs::log::Level::Info, format_args!($($arg)+));
        }
    };
    ($($arg:tt)+) => { $crate::log_info!(target: "ocpd", $($arg)+) };
}

/// Log at [`Level::Debug`] (see [`log_error!`] for the forms).
#[macro_export]
macro_rules! log_debug {
    (target: $target:expr, $($arg:tt)+) => {
        if $crate::obs::log::enabled($target, $crate::obs::log::Level::Debug) {
            $crate::obs::log::write(
                $target,
                $crate::obs::log::Level::Debug,
                format_args!($($arg)+),
            );
        }
    };
    ($($arg:tt)+) => { $crate::log_debug!(target: "ocpd", $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filter_default_and_overrides() {
        let f = Filter::parse("warn,wal=debug,http=error");
        assert_eq!(f.max_level("cutout"), Level::Warn);
        assert_eq!(f.max_level("wal"), Level::Debug);
        assert_eq!(f.max_level("http"), Level::Error);
    }

    #[test]
    fn filter_empty_defaults_to_info() {
        let f = Filter::parse("");
        assert_eq!(f.max_level("anything"), Level::Info);
    }

    #[test]
    fn level_ordering_gates() {
        let f = Filter::parse("info");
        assert!(Level::Error <= f.max_level("x"));
        assert!(Level::Info <= f.max_level("x"));
        assert!(Level::Debug > f.max_level("x"));
    }

    #[test]
    fn macros_expand() {
        // Smoke: both forms compile and run (output goes to stderr).
        log_debug!("suppressed by default n={}", 1);
        log_info!(target: "test", "k={} v={}", "a", 2);
    }
}
