//! Shard heat maps: decaying access counters over the Morton key-space.
//!
//! Every keyed access — cutout reads and writes (cache hits included,
//! since [`crate::chunkstore::CuboidStore`] records before consulting
//! the cache), tile-cache hits, WAL flush applies, job blocks — lands
//! in a [`HeatTracker`] bucketed over the project's Morton key range.
//! Buckets decay under an exponentially weighted moving average with a
//! configurable half-life, so the map answers "what is hot *now*", not
//! "what was ever touched".
//!
//! The bucket grid is strictly finer than (or equal to) the shard grid,
//! so two derived views come for free at snapshot time:
//!
//! * **per-shard heat** — buckets grouped through
//!   [`crate::shard::ShardMap::shard_for`], the ranking behind
//!   `GET /heat/status/` and the `ocpd_heat_*` metric families;
//! * **hot split keys** — [`HeatTracker::hot_split_key`] walks a
//!   shard's buckets to the key where cumulative heat halves, which is
//!   exactly the cut a future dynamic shard splitter (ROADMAP item 1)
//!   needs.
//!
//! Recording is lock-free: accesses add to per-bucket atomic *pending*
//! counters; a snapshot folds pending deltas into the `f64` EWMA state
//! under a mutex, applying `0.5^(dt / half_life)` decay for the elapsed
//! interval. The fold takes an explicit elapsed duration internally, so
//! tests drive decay deterministically via [`HeatTracker::fold_after`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::shard::ShardMap;

/// Weight of one access op in the heat score, in byte-equivalents.
/// Metadata-sized ops (WAL applies, RAMON lookups) move the needle
/// without having to lie about their byte volume.
const OP_WEIGHT: u64 = 1024;

/// A 2x2x2 Morton sibling group: `encode3` visits the whole octant in 8
/// consecutive keys, so a cuboid run never crosses a multiple-of-8 key
/// except at an octant boundary. Split cuts snap here so no split can
/// ever put two halves of one cuboid's run on different shards.
pub const MORTON_BLOCK: u64 = 8;

/// Snap `cut` to the nearest Morton-block boundary strictly inside
/// `(lo, hi)` — the public entry for cold (manual) splits, which cut at
/// the range midpoint instead of a heat median but must respect cuboid
/// runs all the same. `None` when the range holds no interior boundary.
pub fn snap_split_key(cut: u64, lo: u64, hi: u64) -> Option<u64> {
    snap_cut(cut, lo, hi)
}

/// Snap `cut` to the nearest Morton-block boundary strictly inside
/// `(lo, hi)`. `None` when the range holds no interior block boundary
/// (the shard is too small to split without cutting a cuboid run).
fn snap_cut(cut: u64, lo: u64, hi: u64) -> Option<u64> {
    let down = cut - cut % MORTON_BLOCK;
    let up = down.saturating_add(MORTON_BLOCK);
    let ok = |k: u64| k > lo && k < hi;
    match (ok(down), ok(up)) {
        (true, true) => Some(if cut - down <= up - cut { down } else { up }),
        (true, false) => Some(down),
        (false, true) => Some(up),
        (false, false) => None,
    }
}

/// Default bucket count over the key-space (clamped to `total_keys`).
pub const DEFAULT_BUCKETS: usize = 64;

/// Default EWMA half-life.
pub const DEFAULT_HALF_LIFE: Duration = Duration::from_secs(60);

/// Lock-free pending deltas for one key-range bucket.
#[derive(Default)]
struct Pending {
    read_ops: AtomicU64,
    read_bytes: AtomicU64,
    write_ops: AtomicU64,
    write_bytes: AtomicU64,
}

/// Decayed EWMA state for one bucket (guarded by the fold mutex).
#[derive(Clone, Copy, Default)]
struct Ewma {
    read_ops: f64,
    read_bytes: f64,
    write_ops: f64,
    write_bytes: f64,
}

impl Ewma {
    fn score(&self) -> f64 {
        self.read_bytes
            + self.write_bytes
            + OP_WEIGHT as f64 * (self.read_ops + self.write_ops)
    }
}

struct FoldState {
    buckets: Vec<Ewma>,
    last_fold: Instant,
}

/// One bucket of the folded heat map.
#[derive(Clone, Debug)]
pub struct BucketHeat {
    /// Key range `[lo, hi)` this bucket covers.
    pub lo: u64,
    pub hi: u64,
    pub read_ops: f64,
    pub read_bytes: f64,
    pub write_ops: f64,
    pub write_bytes: f64,
    /// `bytes + OP_WEIGHT × ops`, decayed.
    pub score: f64,
}

/// One shard's aggregated heat (buckets grouped by the shard map).
#[derive(Clone, Debug)]
pub struct ShardHeat {
    pub shard: usize,
    /// Key range `[lo, hi)` of the shard.
    pub lo: u64,
    pub hi: u64,
    pub read_ops: f64,
    pub read_bytes: f64,
    pub write_ops: f64,
    pub write_bytes: f64,
    pub score: f64,
}

/// A folded view of the heat map: per-shard ranking plus the raw
/// bucket grid.
#[derive(Clone, Debug)]
pub struct HeatSnapshot {
    /// Shards sorted hottest-first.
    pub shards: Vec<ShardHeat>,
    /// All buckets in key order (including cold ones).
    pub buckets: Vec<BucketHeat>,
    /// Sum of all bucket scores.
    pub total_score: f64,
}

impl HeatSnapshot {
    /// The `k` hottest non-cold buckets, hottest first — the "top-K hot
    /// key ranges" view of `GET /heat/status/`.
    pub fn top_buckets(&self, k: usize) -> Vec<BucketHeat> {
        let mut hot: Vec<BucketHeat> =
            self.buckets.iter().filter(|b| b.score > 0.0).cloned().collect();
        hot.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal));
        hot.truncate(k);
        hot
    }
}

/// Per-project decaying heat map over the Morton key-space.
pub struct HeatTracker {
    total_keys: u64,
    bucket_width: u64,
    pending: Vec<Pending>,
    state: Mutex<FoldState>,
    half_life: Duration,
    /// Shard key ranges `[lo, hi)`, ascending; one entry covering
    /// everything for unsharded (annotation) projects. Swappable: a
    /// split/merge/move rebinds the tracker to the new generation via
    /// [`HeatTracker::set_shards`] without losing bucket state.
    shards: RwLock<Arc<ShardMap>>,
}

impl HeatTracker {
    /// A tracker over `[0, total_keys)` grouped by `shards`, with the
    /// default bucket grid and half-life.
    pub fn new(total_keys: u64, shards: Arc<ShardMap>) -> Self {
        Self::with_config(total_keys, shards, DEFAULT_BUCKETS, DEFAULT_HALF_LIFE)
    }

    /// Explicit bucket count and half-life (tests, tuning).
    pub fn with_config(
        total_keys: u64,
        shards: Arc<ShardMap>,
        buckets: usize,
        half_life: Duration,
    ) -> Self {
        let total_keys = total_keys.max(1);
        let n = (buckets.max(1) as u64).min(total_keys) as usize;
        let bucket_width = total_keys.div_ceil(n as u64).max(1);
        let mut pending = Vec::with_capacity(n);
        pending.resize_with(n, Pending::default);
        HeatTracker {
            total_keys,
            bucket_width,
            pending,
            state: Mutex::new(FoldState {
                buckets: vec![Ewma::default(); n],
                last_fold: Instant::now(),
            }),
            half_life,
            shards: RwLock::new(shards),
        }
    }

    /// Total key-space size this tracker covers.
    pub fn total_keys(&self) -> u64 {
        self.total_keys
    }

    /// Rebind the tracker to a new shard map generation (after a split,
    /// merge, or move). Bucket heat is untouched — only the per-shard
    /// aggregation view changes.
    pub fn set_shards(&self, shards: Arc<ShardMap>) {
        *self.shards.write().unwrap() = shards;
    }

    /// The shard map generation the tracker currently aggregates by.
    pub fn shards(&self) -> Arc<ShardMap> {
        Arc::clone(&self.shards.read().unwrap())
    }

    fn bucket_of(&self, key: u64) -> usize {
        ((key / self.bucket_width) as usize).min(self.pending.len() - 1)
    }

    /// Record one read of `bytes` at Morton `key`. Lock-free.
    pub fn record_read(&self, key: u64, bytes: u64) {
        let b = &self.pending[self.bucket_of(key)];
        b.read_ops.fetch_add(1, Ordering::Relaxed);
        b.read_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record one write of `bytes` at Morton `key`. Lock-free.
    pub fn record_write(&self, key: u64, bytes: u64) {
        let b = &self.pending[self.bucket_of(key)];
        b.write_ops.fetch_add(1, Ordering::Relaxed);
        b.write_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Fold pending deltas into the EWMA state, decaying the existing
    /// state by `0.5^(elapsed / half_life)`.
    fn fold(&self, elapsed: Duration) {
        let mut st = self.state.lock().unwrap();
        let factor = if self.half_life.is_zero() {
            0.0
        } else {
            0.5f64.powf(elapsed.as_secs_f64() / self.half_life.as_secs_f64())
        };
        for (ewma, pend) in st.buckets.iter_mut().zip(&self.pending) {
            ewma.read_ops = ewma.read_ops * factor + pend.read_ops.swap(0, Ordering::Relaxed) as f64;
            ewma.read_bytes =
                ewma.read_bytes * factor + pend.read_bytes.swap(0, Ordering::Relaxed) as f64;
            ewma.write_ops =
                ewma.write_ops * factor + pend.write_ops.swap(0, Ordering::Relaxed) as f64;
            ewma.write_bytes =
                ewma.write_bytes * factor + pend.write_bytes.swap(0, Ordering::Relaxed) as f64;
        }
        st.last_fold = Instant::now();
    }

    /// Deterministic fold: pretend `elapsed` wall time passed since the
    /// last fold. The decay test harness entry point.
    pub fn fold_after(&self, elapsed: Duration) {
        self.fold(elapsed);
    }

    /// Fold with real elapsed time and return the folded view.
    pub fn snapshot(&self) -> HeatSnapshot {
        let elapsed = { self.state.lock().unwrap().last_fold.elapsed() };
        self.fold(elapsed);
        self.snapshot_folded()
    }

    /// The folded view without a new fold (used right after
    /// [`fold_after`](Self::fold_after) in tests).
    pub fn snapshot_folded(&self) -> HeatSnapshot {
        let st = self.state.lock().unwrap();
        let mut buckets = Vec::with_capacity(st.buckets.len());
        for (i, e) in st.buckets.iter().enumerate() {
            let lo = i as u64 * self.bucket_width;
            buckets.push(BucketHeat {
                lo,
                hi: (lo + self.bucket_width).min(self.total_keys),
                read_ops: e.read_ops,
                read_bytes: e.read_bytes,
                write_ops: e.write_ops,
                write_bytes: e.write_bytes,
                score: e.score(),
            });
        }
        let shard_map = self.shards();
        let mut shards: Vec<ShardHeat> = (0..shard_map.num_shards())
            .map(|s| {
                let (lo, hi) = shard_map.shard_range(s);
                ShardHeat {
                    shard: s,
                    lo,
                    hi,
                    read_ops: 0.0,
                    read_bytes: 0.0,
                    write_ops: 0.0,
                    write_bytes: 0.0,
                    score: 0.0,
                }
            })
            .collect();
        for b in &buckets {
            // Buckets never straddle shards when the bucket grid is
            // finer; attribute by the bucket's low key either way.
            let s = shard_map.shard_for(b.lo.min(self.total_keys - 1));
            if let Some(sh) = shards.get_mut(s) {
                sh.read_ops += b.read_ops;
                sh.read_bytes += b.read_bytes;
                sh.write_ops += b.write_ops;
                sh.write_bytes += b.write_bytes;
                sh.score += b.score;
            }
        }
        let total_score = buckets.iter().map(|b| b.score).sum();
        shards.sort_by(|a, b| {
            b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal)
        });
        HeatSnapshot { shards, buckets, total_score }
    }

    /// The key within shard `shard` where cumulative heat reaches half
    /// of the shard's total — the split point the dynamic shard splitter
    /// cuts at, snapped to a Morton-block ([`MORTON_BLOCK`]) boundary so
    /// the two halves of one cuboid's run can never land on different
    /// shards. `None` when the shard is cold (no heat to split) or too
    /// small to hold an interior block boundary.
    pub fn hot_split_key(&self, shard: usize) -> Option<u64> {
        let snap = self.snapshot();
        let (lo, hi) = self.shards().shard_range(shard);
        let in_shard: Vec<&BucketHeat> =
            snap.buckets.iter().filter(|b| b.lo >= lo && b.lo < hi).collect();
        let total: f64 = in_shard.iter().map(|b| b.score).sum();
        if total <= 0.0 {
            return None;
        }
        let mut acc = 0.0;
        for b in &in_shard {
            acc += b.score;
            if acc >= total / 2.0 {
                // Cut *after* the bucket that crosses the midpoint, but
                // never at the shard boundary itself.
                let raw = b.hi.min(hi.saturating_sub(1)).max(lo + 1);
                return snap_cut(raw, lo, hi);
            }
        }
        snap_cut(hi.saturating_sub(1).max(lo + 1), lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracker(total: u64, nodes: usize, buckets: usize) -> HeatTracker {
        let map = Arc::new(
            ShardMap::even(total, (0..nodes).collect()).expect("even shard map"),
        );
        HeatTracker::with_config(total, map, buckets, Duration::from_secs(60))
    }

    #[test]
    fn records_land_in_the_right_bucket_and_shard() {
        let t = tracker(1024, 2, 8); // 2 shards of 512 keys, 8 buckets of 128
        t.record_read(0, 1000);
        t.record_write(1023, 500);
        t.fold_after(Duration::ZERO);
        let snap = t.snapshot_folded();
        assert_eq!(snap.buckets.len(), 8);
        assert_eq!(snap.buckets[0].read_bytes, 1000.0);
        assert_eq!(snap.buckets[7].write_bytes, 500.0);
        // Shard ranking: shard 0 got 1000 bytes + 1 op, shard 1 got 500 + 1.
        assert_eq!(snap.shards[0].shard, 0);
        assert!(snap.shards[0].score > snap.shards[1].score);
        assert_eq!(snap.total_score, snap.shards.iter().map(|s| s.score).sum::<f64>());
    }

    #[test]
    fn ewma_decays_by_half_life() {
        let t = tracker(256, 1, 4);
        t.record_read(0, 1 << 20);
        t.fold_after(Duration::ZERO); // fold the pending in, no decay
        let before = t.snapshot_folded().total_score;
        t.fold_after(Duration::from_secs(60)); // exactly one half-life
        let after = t.snapshot_folded().total_score;
        assert!((after - before / 2.0).abs() < 1e-6, "{after} != {before}/2");
        // A second half-life quarters the original.
        t.fold_after(Duration::from_secs(60));
        let quarter = t.snapshot_folded().total_score;
        assert!((quarter - before / 4.0).abs() < 1e-6);
    }

    #[test]
    fn fresh_traffic_dominates_decayed_history() {
        let t = tracker(1024, 2, 8);
        // Old heat on shard 1's half of the key-space…
        t.record_read(700, 1 << 20);
        t.fold_after(Duration::ZERO);
        // …ten half-lives later, light traffic on shard 0 wins.
        t.record_read(10, 4096);
        t.fold_after(Duration::from_secs(600));
        let snap = t.snapshot_folded();
        assert_eq!(snap.shards[0].shard, 0, "fresh traffic should rank first");
    }

    #[test]
    fn top_buckets_are_sorted_and_skip_cold() {
        let t = tracker(1024, 1, 8);
        t.record_read(0, 10);
        t.record_read(500, 10_000);
        t.fold_after(Duration::ZERO);
        let top = t.snapshot_folded().top_buckets(10);
        assert_eq!(top.len(), 2, "cold buckets must not appear");
        assert!(top[0].score > top[1].score);
        assert!(top[0].lo <= 500 && 500 < top[0].hi);
    }

    #[test]
    fn hot_split_key_lands_at_the_heat_median() {
        let t = tracker(1024, 1, 8); // one shard, buckets of 128
        // All heat in the last bucket: the split must land near it.
        t.record_read(1000, 1 << 20);
        t.fold_after(Duration::ZERO);
        let split = t.hot_split_key(0).expect("hot shard splits");
        assert!(split > 896, "split {split} should isolate the hot tail bucket");
        // Cold shard has nothing to split.
        let cold = tracker(1024, 1, 8);
        assert_eq!(cold.hot_split_key(0), None);
    }

    #[test]
    fn hot_split_key_snaps_to_a_morton_block_boundary() {
        // 10 buckets over 1024 keys: bucket_width = 103, so every raw
        // bucket edge (103, 206, …) lands mid-octant. The cut must snap
        // to a multiple of MORTON_BLOCK anyway.
        let t = tracker(1024, 1, 10);
        t.record_read(50, 1 << 20);
        t.fold_after(Duration::ZERO);
        let split = t.hot_split_key(0).expect("hot shard splits");
        assert_eq!(split % MORTON_BLOCK, 0, "cut {split} is mid-cuboid");
        // The raw median is bucket 0's hi = 103; the nearest block
        // boundary is 104 — a cuboid's 8-key run [96, 104) stays whole.
        assert_eq!(split, 104);
    }

    #[test]
    fn hot_split_key_refuses_sub_block_shards() {
        // Shard 0 owns [0, 4): hot, but no interior multiple of 8 — a
        // split would necessarily cut a cuboid run, so there is none.
        let map = Arc::new(ShardMap::new(vec![4], vec![0, 1]).unwrap());
        let t = HeatTracker::with_config(1024, map, 256, Duration::from_secs(60));
        t.record_read(1, 1 << 20);
        t.record_read(2, 1 << 20);
        assert_eq!(t.hot_split_key(0), None);
    }

    #[test]
    fn set_shards_rebinds_the_aggregation_view() {
        let t = tracker(1024, 1, 8);
        t.record_read(1000, 1 << 20);
        t.fold_after(Duration::ZERO);
        assert_eq!(t.snapshot_folded().shards.len(), 1);
        // Rebinding to a post-split map regroups the same buckets.
        let split = t.shards().split(0, 512).unwrap();
        t.set_shards(Arc::new(split));
        let snap = t.snapshot_folded();
        assert_eq!(snap.shards.len(), 2);
        assert_eq!(snap.shards[0].shard, 1, "heat is all in the upper half");
        assert_eq!(snap.shards[0].lo, 512);
    }

    #[test]
    fn tiny_keyspaces_clamp_the_bucket_grid() {
        let t = tracker(4, 1, 64);
        t.record_read(3, 7);
        t.fold_after(Duration::ZERO);
        let snap = t.snapshot_folded();
        assert_eq!(snap.buckets.len(), 4);
        assert_eq!(snap.buckets[3].read_bytes, 7.0);
    }
}
