//! Span tracing with tail-based retention.
//!
//! One [`Trace`] per request (or per job run), a tree of spans under
//! it. The tracer is a process-wide singleton so deep layers (the WAL
//! group-commit leader, a shard fan-out worker) can attach spans
//! without plumbing a handle through every signature: the active trace
//! rides a thread-local stack, and [`scoped_map`] propagates it onto
//! fork-join workers via [`current`]/[`install`].
//!
//! Retention is decided **after** a trace completes (tail-based): every
//! live trace records its full span tree, and at completion a trace
//! slower than the configured threshold always lands in the slow ring,
//! while the rest are 1-in-N sampled into the recent ring. `Off` mode
//! records nothing — span creation is a no-op costing one atomic load.
//!
//! [`scoped_map`]: crate::util::pool::scoped_map

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::metrics::Counter;

/// How much the tracer records. Retention (slow ring / sampling) is
/// decided at trace completion; the mode gates span *recording*.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceMode {
    /// Record nothing; every tracing call is a no-op.
    Off = 0,
    /// Record every trace; keep slow ones always, sample the rest 1-in-N
    /// into the recent ring (the default).
    Sampled = 1,
    /// Record and retain every trace.
    Always = 2,
}

/// Tracer tuning. Built from the environment once
/// (`OCPD_TRACE=off|sampled|always`, `OCPD_TRACE_SAMPLE_N`,
/// `OCPD_TRACE_SLOW_US`); benches and tests override via
/// [`Tracer::configure`].
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    pub mode: TraceMode,
    /// Keep 1 in this many fast traces (the slow ring is unconditional).
    pub sample_every: u64,
    /// Traces at least this slow always land in the slow ring.
    pub slow_threshold_us: u64,
    /// Capacity of each retention ring (recent and slow).
    pub capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            mode: TraceMode::Sampled,
            sample_every: 64,
            slow_threshold_us: 100_000,
            capacity: 256,
        }
    }
}

impl TraceConfig {
    /// The default overridden by `OCPD_TRACE*` environment variables.
    pub fn from_env() -> Self {
        let mut cfg = TraceConfig::default();
        match std::env::var("OCPD_TRACE").ok().as_deref() {
            Some("off") => cfg.mode = TraceMode::Off,
            Some("always") => cfg.mode = TraceMode::Always,
            Some(_) | None => {}
        }
        if let Some(n) = std::env::var("OCPD_TRACE_SAMPLE_N").ok().and_then(|v| v.parse().ok())
        {
            cfg.sample_every = std::cmp::max(n, 1);
        }
        if let Some(us) = std::env::var("OCPD_TRACE_SLOW_US").ok().and_then(|v| v.parse().ok())
        {
            cfg.slow_threshold_us = us;
        }
        cfg
    }
}

/// One finished span: its position in the tree (`parent` = 0 for the
/// root), the layer that opened it, wall-clock offsets relative to the
/// trace start, and free-form tags.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub id: u64,
    /// Parent span id; 0 marks the root.
    pub parent: u64,
    /// The subsystem that opened the span ("http", "cutout", "cache",
    /// "shard", "wal", "job").
    pub layer: &'static str,
    pub name: String,
    /// Microseconds from trace start to span start.
    pub start_us: u64,
    pub dur_us: u64,
    pub tags: Vec<(&'static str, String)>,
}

/// A completed, retained trace.
#[derive(Debug)]
pub struct FinishedTrace {
    pub request_id: String,
    pub dur_us: u64,
    /// Spans in completion order; rebuild the tree via `parent` ids.
    pub spans: Vec<SpanRecord>,
}

/// Live trace state shared by every span guard on its path.
#[derive(Debug)]
pub struct TraceInner {
    request_id: String,
    start: Instant,
    spans: Mutex<Vec<SpanRecord>>,
}

/// A captured (trace, active span) pair — the thread-local context at
/// the moment of capture, installable on another thread.
#[derive(Clone)]
pub struct TraceCtx {
    trace: Arc<TraceInner>,
    span: u64,
}

thread_local! {
    /// Stack of (trace, span id) frames; the top is the active span new
    /// children attach to.
    static CURRENT: RefCell<Vec<(Arc<TraceInner>, u64)>> = const { RefCell::new(Vec::new()) };
}

/// Trace lifecycle counters, surfaced at `GET /trace/status/`.
#[derive(Debug, Default)]
pub struct TraceCounters {
    pub started: Counter,
    pub finished: Counter,
    pub retained_slow: Counter,
    pub retained_sampled: Counter,
    pub dropped: Counter,
}

/// The process-wide tracer: configuration, id allocator, counters, and
/// the two retention rings.
pub struct Tracer {
    mode: AtomicU8,
    sample_every: AtomicU64,
    slow_threshold_us: AtomicU64,
    capacity: AtomicU64,
    /// Span/trace id allocator (ids are process-unique, never 0).
    seq: AtomicU64,
    /// Completed-trace count driving the 1-in-N sampling decision.
    completed: AtomicU64,
    pub counters: TraceCounters,
    recent: Mutex<VecDeque<Arc<FinishedTrace>>>,
    slow: Mutex<VecDeque<Arc<FinishedTrace>>>,
}

impl Tracer {
    pub fn new(cfg: TraceConfig) -> Self {
        Tracer {
            mode: AtomicU8::new(cfg.mode as u8),
            sample_every: AtomicU64::new(cfg.sample_every.max(1)),
            slow_threshold_us: AtomicU64::new(cfg.slow_threshold_us),
            capacity: AtomicU64::new(cfg.capacity as u64),
            seq: AtomicU64::new(1),
            completed: AtomicU64::new(0),
            counters: TraceCounters::default(),
            recent: Mutex::new(VecDeque::new()),
            slow: Mutex::new(VecDeque::new()),
        }
    }

    /// Replace the tuning knobs on a live tracer (benches, tests, and
    /// an operator toggling tracing without a restart).
    pub fn configure(&self, cfg: TraceConfig) {
        self.mode.store(cfg.mode as u8, Ordering::Relaxed);
        self.sample_every.store(cfg.sample_every.max(1), Ordering::Relaxed);
        self.slow_threshold_us.store(cfg.slow_threshold_us, Ordering::Relaxed);
        self.capacity.store(cfg.capacity as u64, Ordering::Relaxed);
    }

    pub fn config(&self) -> TraceConfig {
        TraceConfig {
            mode: match self.mode.load(Ordering::Relaxed) {
                0 => TraceMode::Off,
                2 => TraceMode::Always,
                _ => TraceMode::Sampled,
            },
            sample_every: self.sample_every.load(Ordering::Relaxed),
            slow_threshold_us: self.slow_threshold_us.load(Ordering::Relaxed),
            capacity: self.capacity.load(Ordering::Relaxed) as usize,
        }
    }

    fn enabled(&self) -> bool {
        self.mode.load(Ordering::Relaxed) != TraceMode::Off as u8
    }

    pub fn next_id(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Retained traces, newest first.
    pub fn recent(&self) -> Vec<Arc<FinishedTrace>> {
        self.recent.lock().unwrap().iter().rev().cloned().collect()
    }

    /// Slow traces (above threshold), newest first.
    pub fn slow(&self) -> Vec<Arc<FinishedTrace>> {
        self.slow.lock().unwrap().iter().rev().cloned().collect()
    }

    /// Drop all retained traces (tests and the bench harness).
    pub fn clear(&self) {
        self.recent.lock().unwrap().clear();
        self.slow.lock().unwrap().clear();
    }

    fn finish(&self, inner: Arc<TraceInner>, dur_us: u64) {
        self.counters.finished.inc();
        let cfg = self.config();
        let slow = dur_us >= cfg.slow_threshold_us;
        let n = self.completed.fetch_add(1, Ordering::Relaxed);
        let sampled = cfg.mode == TraceMode::Always || n % cfg.sample_every == 0;
        if !slow && !sampled {
            self.counters.dropped.inc();
            return;
        }
        let spans = std::mem::take(&mut *inner.spans.lock().unwrap());
        let done = Arc::new(FinishedTrace {
            request_id: inner.request_id.clone(),
            dur_us,
            spans,
        });
        if slow {
            self.counters.retained_slow.inc();
            push_ring(&self.slow, Arc::clone(&done), cfg.capacity);
        }
        if sampled {
            self.counters.retained_sampled.inc();
            push_ring(&self.recent, done, cfg.capacity);
        }
    }

    /// The `GET /trace/status/` body.
    pub fn status_text(&self) -> String {
        let cfg = self.config();
        let c = &self.counters;
        let mode = match cfg.mode {
            TraceMode::Off => "off",
            TraceMode::Sampled => "sampled",
            TraceMode::Always => "always",
        };
        format!(
            "trace:\n  mode={mode} sample_every={} slow_threshold_us={} capacity={}\n  \
             started={} finished={} retained_slow={} retained_sampled={} dropped={}\n  \
             rings: recent={} slow={}\n",
            cfg.sample_every,
            cfg.slow_threshold_us,
            cfg.capacity,
            c.started.get(),
            c.finished.get(),
            c.retained_slow.get(),
            c.retained_sampled.get(),
            c.dropped.get(),
            self.recent.lock().unwrap().len(),
            self.slow.lock().unwrap().len(),
        )
    }
}

fn push_ring(ring: &Mutex<VecDeque<Arc<FinishedTrace>>>, t: Arc<FinishedTrace>, cap: usize) {
    let mut g = ring.lock().unwrap();
    while g.len() >= cap.max(1) {
        g.pop_front();
    }
    g.push_back(t);
}

/// The process-wide tracer, configured from the environment on first
/// touch.
pub fn tracer() -> &'static Tracer {
    static TRACER: OnceLock<Tracer> = OnceLock::new();
    TRACER.get_or_init(|| Tracer::new(TraceConfig::from_env()))
}

/// Open a root span and make its trace current on this thread. The
/// guard finishes the trace (and decides retention) on drop. A no-op
/// when tracing is off.
pub fn start_trace(layer: &'static str, name: impl Into<String>, request_id: &str) -> TraceGuard {
    let t = tracer();
    if !t.enabled() {
        return TraceGuard(None);
    }
    t.counters.started.inc();
    let inner = Arc::new(TraceInner {
        request_id: request_id.to_string(),
        start: Instant::now(),
        spans: Mutex::new(Vec::new()),
    });
    let id = t.next_id();
    CURRENT.with(|c| c.borrow_mut().push((Arc::clone(&inner), id)));
    TraceGuard(Some(SpanState {
        trace: inner,
        id,
        parent: 0,
        layer,
        name: name.into(),
        started: Instant::now(),
        tags: Vec::new(),
    }))
}

/// Open a child span under the thread's current trace. A no-op (one
/// thread-local read) when no trace is active.
pub fn span(layer: &'static str, name: impl Into<String>) -> SpanGuard {
    let Some((trace, parent)) = CURRENT.with(|c| c.borrow().last().cloned()) else {
        return SpanGuard(None);
    };
    let id = tracer().next_id();
    CURRENT.with(|c| c.borrow_mut().push((Arc::clone(&trace), id)));
    SpanGuard(Some(SpanState {
        trace,
        id,
        parent,
        layer,
        name: name.into(),
        started: Instant::now(),
        tags: Vec::new(),
    }))
}

/// The thread's current (trace, span) context, for handing to another
/// thread (see [`install`]).
pub fn current() -> Option<TraceCtx> {
    CURRENT.with(|c| c.borrow().last().cloned()).map(|(trace, span)| TraceCtx { trace, span })
}

/// The active trace's request id, if any — log correlation and the
/// client's outbound `X-Request-Id` propagation.
pub fn current_request_id() -> Option<String> {
    CURRENT.with(|c| c.borrow().last().map(|(t, _)| t.request_id.clone()))
}

/// Make a captured context current on this thread (a fork-join worker);
/// the guard uninstalls it on drop. `None` installs nothing.
pub fn install(ctx: Option<TraceCtx>) -> InstallGuard {
    match ctx {
        Some(TraceCtx { trace, span }) => {
            CURRENT.with(|c| c.borrow_mut().push((trace, span)));
            InstallGuard(true)
        }
        None => InstallGuard(false),
    }
}

/// Uninstalls an [`install`]ed context on drop.
pub struct InstallGuard(bool);

impl Drop for InstallGuard {
    fn drop(&mut self) {
        if self.0 {
            CURRENT.with(|c| {
                c.borrow_mut().pop();
            });
        }
    }
}

struct SpanState {
    trace: Arc<TraceInner>,
    id: u64,
    parent: u64,
    layer: &'static str,
    name: String,
    started: Instant,
    tags: Vec<(&'static str, String)>,
}

impl SpanState {
    fn record(self) -> Arc<TraceInner> {
        let rec = SpanRecord {
            id: self.id,
            parent: self.parent,
            layer: self.layer,
            name: self.name,
            start_us: self
                .started
                .duration_since(self.trace.start)
                .as_micros() as u64,
            dur_us: self.started.elapsed().as_micros() as u64,
            tags: self.tags,
        };
        self.trace.spans.lock().unwrap().push(rec);
        self.trace
    }
}

/// Root-span guard: finishes the span *and* the trace on drop.
pub struct TraceGuard(Option<SpanState>);

impl TraceGuard {
    pub fn tag(&mut self, key: &'static str, value: impl Into<String>) {
        if let Some(s) = self.0.as_mut() {
            s.tags.push((key, value.into()));
        }
    }

    /// Whether this guard carries a live trace (false when tracing is
    /// off).
    pub fn is_active(&self) -> bool {
        self.0.is_some()
    }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        if let Some(state) = self.0.take() {
            CURRENT.with(|c| {
                c.borrow_mut().pop();
            });
            let trace = state.record();
            let dur_us = trace.start.elapsed().as_micros() as u64;
            tracer().finish(trace, dur_us);
        }
    }
}

/// Child-span guard: records the span on drop.
pub struct SpanGuard(Option<SpanState>);

impl SpanGuard {
    pub fn tag(&mut self, key: &'static str, value: impl Into<String>) {
        if let Some(s) = self.0.as_mut() {
            s.tags.push((key, value.into()));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(state) = self.0.take() {
            CURRENT.with(|c| {
                c.borrow_mut().pop();
            });
            state.record();
        }
    }
}

/// Render retained traces as the indented text tree served by
/// `GET /trace/recent/` and `GET /trace/slow/`.
pub fn render_traces(traces: &[Arc<FinishedTrace>]) -> String {
    let mut out = String::new();
    if traces.is_empty() {
        out.push_str("(no traces retained)\n");
        return out;
    }
    for t in traces {
        out.push_str(&format!(
            "trace req={} dur_us={} spans={}\n",
            t.request_id,
            t.dur_us,
            t.spans.len()
        ));
        // Rebuild the tree from parent ids; spans are stored in
        // completion order, so sort children by start offset.
        let roots: Vec<&SpanRecord> = t.spans.iter().filter(|s| s.parent == 0).collect();
        for root in roots {
            render_span(&mut out, t, root, 1);
        }
    }
    out
}

fn render_span(out: &mut String, t: &FinishedTrace, s: &SpanRecord, depth: usize) {
    out.push_str(&"  ".repeat(depth));
    out.push_str(&format!(
        "[{}] {} start_us={} dur_us={}",
        s.layer, s.name, s.start_us, s.dur_us
    ));
    for (k, v) in &s.tags {
        out.push_str(&format!(" {k}={v}"));
    }
    out.push('\n');
    let mut children: Vec<&SpanRecord> = t.spans.iter().filter(|c| c.parent == s.id).collect();
    children.sort_by_key(|c| c.start_us);
    for c in children {
        render_span(out, t, c, depth + 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests exercise standalone `Tracer` instances plus the
    // span-guard plumbing; global-config mutation lives in the
    // integration tests (`tests/obs_trace.rs`), which run in their own
    // process.

    #[test]
    fn ring_retention_bounds() {
        let t = Tracer::new(TraceConfig {
            mode: TraceMode::Always,
            sample_every: 1,
            slow_threshold_us: 0,
            capacity: 3,
        });
        for i in 0..10 {
            let inner = Arc::new(TraceInner {
                request_id: format!("r{i}"),
                start: Instant::now(),
                spans: Mutex::new(Vec::new()),
            });
            t.finish(inner, 5);
        }
        assert_eq!(t.slow().len(), 3);
        assert_eq!(t.recent().len(), 3);
        // Newest first.
        assert_eq!(t.slow()[0].request_id, "r9");
    }

    #[test]
    fn sampling_keeps_one_in_n() {
        let t = Tracer::new(TraceConfig {
            mode: TraceMode::Sampled,
            sample_every: 4,
            slow_threshold_us: u64::MAX,
            capacity: 64,
        });
        for i in 0..16 {
            let inner = Arc::new(TraceInner {
                request_id: format!("r{i}"),
                start: Instant::now(),
                spans: Mutex::new(Vec::new()),
            });
            t.finish(inner, 1);
        }
        assert_eq!(t.recent().len(), 4);
        assert_eq!(t.slow().len(), 0);
        assert_eq!(t.counters.dropped.get(), 12);
    }

    #[test]
    fn slow_always_kept() {
        let t = Tracer::new(TraceConfig {
            mode: TraceMode::Sampled,
            sample_every: 1_000_000,
            slow_threshold_us: 100,
            capacity: 64,
        });
        for i in 0..8 {
            let inner = Arc::new(TraceInner {
                request_id: format!("r{i}"),
                start: Instant::now(),
                spans: Mutex::new(Vec::new()),
            });
            // Odd traces are slow.
            t.finish(inner, if i % 2 == 1 { 500 } else { 5 });
        }
        assert_eq!(t.slow().len(), 4);
    }

    #[test]
    fn render_tree_indents_children() {
        let t = FinishedTrace {
            request_id: "abc".into(),
            dur_us: 1000,
            spans: vec![
                SpanRecord {
                    id: 2,
                    parent: 1,
                    layer: "cutout",
                    name: "read".into(),
                    start_us: 10,
                    dur_us: 900,
                    tags: vec![("res", "0".into())],
                },
                SpanRecord {
                    id: 1,
                    parent: 0,
                    layer: "http",
                    name: "GET /x/".into(),
                    start_us: 0,
                    dur_us: 1000,
                    tags: vec![],
                },
            ],
        };
        let s = render_traces(&[Arc::new(t)]);
        assert!(s.contains("trace req=abc"));
        assert!(s.contains("  [http] GET /x/"));
        assert!(s.contains("    [cutout] read"), "{s}");
        assert!(s.contains("res=0"));
    }
}
