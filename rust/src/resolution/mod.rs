//! The multi-resolution hierarchy: XY-halving builds for image databases
//! and background propagation for annotation databases (§3.1, §3.2,
//! Figure 5).
//!
//! Each level halves X and Y but never Z (sections are poorly resolved),
//! time, or channels. Annotations are written at a single level and
//! propagated to all others "as a background, batch I/O job" — the paper
//! deliberately sacrifices instantaneous cross-resolution consistency for
//! write throughput; [`Propagator`] is the one-shot, synchronous form of
//! that job. The production form is [`crate::jobs::PropagateJob`]: the
//! same per-level downsamples ([`downsample_mean_u8`],
//! [`downsample_labels_u32`]) driven as a checkpointed, parallel batch
//! job whose blocks reuse each freshly-built level in memory as the next
//! level's input (in bands of up to three levels), instead of re-reading
//! it from storage per destination level — halving the read I/O per
//! level. Outputs are identical; the
//! `propagate_job_matches_one_shot_propagator` integration tests assert
//! byte parity.

#[cfg(test)]
use std::sync::Arc;

use crate::array::DenseVolume;
use crate::core::Box3;
use crate::cutout::CutoutService;
use crate::util::pool::scoped_map;
use crate::Result;

/// Downsample a volume by 2x in X and Y with box-mean filtering (image
/// data). Z is untouched. Odd extents truncate (matching the level dims).
pub fn downsample_mean_u8(src: &DenseVolume<u8>) -> DenseVolume<u8> {
    let [sx, sy, sz] = src.dims();
    let (dx, dy) = (sx / 2, sy / 2);
    let mut out = DenseVolume::zeros([dx.max(1), dy.max(1), sz]);
    if dx == 0 || dy == 0 {
        return out;
    }
    for z in 0..sz {
        for y in 0..dy {
            for x in 0..dx {
                let s = src.get([2 * x, 2 * y, z]) as u16
                    + src.get([2 * x + 1, 2 * y, z]) as u16
                    + src.get([2 * x, 2 * y + 1, z]) as u16
                    + src.get([2 * x + 1, 2 * y + 1, z]) as u16;
                out.set([x, y, z], (s / 4) as u8);
            }
        }
    }
    out
}

/// Downsample annotation labels by 2x in X and Y: the 2x2 window's
/// majority non-zero label wins (ties: smallest id — deterministic).
/// Mean filtering would invent ids, so labels get a vote instead.
pub fn downsample_labels_u32(src: &DenseVolume<u32>) -> DenseVolume<u32> {
    let [sx, sy, sz] = src.dims();
    let (dx, dy) = (sx / 2, sy / 2);
    let mut out = DenseVolume::zeros([dx.max(1), dy.max(1), sz]);
    if dx == 0 || dy == 0 {
        return out;
    }
    for z in 0..sz {
        for y in 0..dy {
            for x in 0..dx {
                let w = [
                    src.get([2 * x, 2 * y, z]),
                    src.get([2 * x + 1, 2 * y, z]),
                    src.get([2 * x, 2 * y + 1, z]),
                    src.get([2 * x + 1, 2 * y + 1, z]),
                ];
                out.set([x, y, z], majority_nonzero(w));
            }
        }
    }
    out
}

/// Majority non-zero element of a 2x2 window (ties -> smallest id).
fn majority_nonzero(mut w: [u32; 4]) -> u32 {
    w.sort_unstable();
    // After sorting, equal labels are adjacent; scan for the best run
    // among non-zero values.
    let (mut best, mut best_n) = (0u32, 0u32);
    let mut i = 0;
    while i < 4 {
        let v = w[i];
        let mut n = 1;
        while i + n < 4 && w[i + n] == v {
            n += 1;
        }
        if v != 0 && (n as u32 > best_n) {
            best = v;
            best_n = n as u32;
        }
        i += n;
    }
    best
}

/// Background hierarchy builder. Drives one [`CutoutService`] (one
/// project), producing level `l` from level `l-1` cuboid by cuboid.
pub struct Propagator<'a> {
    svc: &'a CutoutService,
    /// Worker threads for the per-cuboid fan-out (a batch I/O job).
    pub parallelism: usize,
}

impl<'a> Propagator<'a> {
    pub fn new(svc: &'a CutoutService) -> Self {
        Propagator { svc, parallelism: 4 }
    }

    /// Build level `dst_res` of an image database from `dst_res - 1`.
    pub fn build_image_level(&self, dst_res: u32) -> Result<u64> {
        self.build_level(dst_res, downsample_mean_u8)
    }

    /// Build level `dst_res` of an annotation database from `dst_res - 1`.
    pub fn build_annotation_level(&self, dst_res: u32) -> Result<u64> {
        self.build_level(dst_res, downsample_labels_u32)
    }

    /// Build every level above the project's base resolution.
    pub fn propagate_image(&self) -> Result<u64> {
        let levels = self.svc.store().dataset.num_levels();
        let base = self.svc.store().project.base_resolution;
        let mut total = 0;
        for res in base + 1..levels {
            total += self.build_image_level(res)?;
        }
        Ok(total)
    }

    /// Propagate annotations from the base resolution to all coarser
    /// levels — the paper's background batch job (§3.2).
    pub fn propagate_annotations(&self) -> Result<u64> {
        let levels = self.svc.store().dataset.num_levels();
        let base = self.svc.store().project.base_resolution;
        let mut total = 0;
        for res in base + 1..levels {
            total += self.build_annotation_level(res)?;
        }
        Ok(total)
    }

    fn build_level<T: crate::array::VoxelScalar>(
        &self,
        dst_res: u32,
        down: fn(&DenseVolume<T>) -> DenseVolume<T>,
    ) -> Result<u64> {
        assert!(dst_res >= 1, "level 0 is the source");
        let ds = &self.svc.store().dataset;
        let dst = ds.level(dst_res)?.clone();
        let src = ds.level(dst_res - 1)?.clone();
        let grid = dst.grid();

        // Enumerate destination cuboids; skip ones whose source region is
        // empty by reading lazily (cutout returns zeros -> all_zero).
        let mut coords = Vec::new();
        for cz in 0..grid[2] {
            for cy in 0..grid[1] {
                for cx in 0..grid[0] {
                    coords.push([cx, cy, cz]);
                }
            }
        }
        let results = scoped_map(coords.len(), self.parallelism, |i| -> Result<u64> {
            let c = coords[i];
            let dst_box = Box3::at(
                [c[0] * dst.cuboid[0], c[1] * dst.cuboid[1], c[2] * dst.cuboid[2]],
                dst.cuboid,
            )
            .intersect(&dst.bounds());
            if dst_box.is_empty() {
                return Ok(0);
            }
            // Source region: 2x in XY, same Z, clipped to source bounds.
            let src_box = Box3::new(
                [dst_box.lo[0] * 2, dst_box.lo[1] * 2, dst_box.lo[2]],
                [
                    (dst_box.hi[0] * 2).min(src.dims[0]),
                    (dst_box.hi[1] * 2).min(src.dims[1]),
                    dst_box.hi[2].min(src.dims[2]),
                ],
            );
            if src_box.is_empty() {
                return Ok(0);
            }
            let sv = self.svc.read::<T>(dst_res - 1, 0, 0, src_box)?;
            if sv.all_zero() {
                return Ok(0); // lazy: nothing to materialize
            }
            let dv = down(&sv);
            let real_dst = Box3::new(
                dst_box.lo,
                [
                    dst_box.lo[0] + dv.dims()[0].min(dst_box.extent()[0]),
                    dst_box.lo[1] + dv.dims()[1].min(dst_box.extent()[1]),
                    dst_box.lo[2] + dv.dims()[2].min(dst_box.extent()[2]),
                ],
            );
            let dv = dv.extract_box(Box3::new([0, 0, 0], real_dst.extent()));
            self.svc.write(dst_res, 0, 0, real_dst, &dv)?;
            Ok(1)
        });
        let mut built = 0;
        for r in results {
            built += r?;
        }
        Ok(built)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunkstore::CuboidStore;
    use crate::core::{DatasetBuilder, Project};
    use crate::storage::MemStore;
    use crate::util::Rng;

    fn image_service(dims: [u64; 3], levels: u32) -> CutoutService {
        let ds = Arc::new(DatasetBuilder::new("t", dims).levels(levels).build());
        let pr = Arc::new(Project::image("img", "t"));
        CutoutService::new(Arc::new(CuboidStore::new(ds, pr, Arc::new(MemStore::new()))))
    }

    fn anno_service(dims: [u64; 3], levels: u32) -> CutoutService {
        let ds = Arc::new(DatasetBuilder::new("t", dims).levels(levels).build());
        let pr = Arc::new(Project::annotation("ann", "t"));
        CutoutService::new(Arc::new(CuboidStore::new(ds, pr, Arc::new(MemStore::new()))))
    }

    #[test]
    fn mean_downsample_exact() {
        let v = DenseVolume::<u8>::from_vec([2, 2, 1], vec![10, 20, 30, 40]).unwrap();
        let d = downsample_mean_u8(&v);
        assert_eq!(d.dims(), [1, 1, 1]);
        assert_eq!(d.get([0, 0, 0]), 25);
    }

    #[test]
    fn label_downsample_majority() {
        // Window (7, 7, 9, 0): 7 wins with two votes.
        let v = DenseVolume::<u32>::from_vec([2, 2, 1], vec![7, 7, 9, 0]).unwrap();
        assert_eq!(downsample_labels_u32(&v).get([0, 0, 0]), 7);
        // Tie (7, 9): smallest id wins.
        let v = DenseVolume::<u32>::from_vec([2, 2, 1], vec![9, 7, 9, 7]).unwrap();
        assert_eq!(downsample_labels_u32(&v).get([0, 0, 0]), 7);
        // All zero stays zero.
        let v = DenseVolume::<u32>::zeros([2, 2, 1]);
        assert_eq!(downsample_labels_u32(&v).get([0, 0, 0]), 0);
    }

    #[test]
    fn majority_nonzero_cases() {
        assert_eq!(majority_nonzero([0, 0, 0, 0]), 0);
        assert_eq!(majority_nonzero([5, 0, 0, 0]), 5);
        assert_eq!(majority_nonzero([5, 5, 3, 3]), 3); // tie -> smallest
        assert_eq!(majority_nonzero([5, 5, 5, 3]), 5);
        assert_eq!(majority_nonzero([1, 2, 3, 4]), 1);
    }

    #[test]
    fn image_hierarchy_constant_volume() {
        // A constant volume stays constant at every level.
        let svc = image_service([256, 256, 32], 3);
        let whole = Box3::new([0, 0, 0], [256, 256, 32]);
        let mut v = DenseVolume::<u8>::zeros(whole.extent());
        v.fill_box(whole, 100);
        svc.write(0, 0, 0, whole, &v).unwrap();
        let built = Propagator::new(&svc).propagate_image().unwrap();
        assert!(built > 0);
        for res in 1..3u32 {
            let dims = svc.store().dataset.level(res).unwrap().dims;
            let out = svc.read::<u8>(res, 0, 0, Box3::new([0, 0, 0], dims)).unwrap();
            assert_eq!(out.count_eq(100), dims[0] * dims[1] * dims[2], "res {res}");
        }
    }

    #[test]
    fn image_hierarchy_mean_of_random() {
        let svc = image_service([128, 128, 16], 2);
        let whole = Box3::new([0, 0, 0], [128, 128, 16]);
        let mut rng = Rng::new(17);
        let n = whole.volume() as usize;
        let v = DenseVolume::<u8>::from_vec(
            whole.extent(),
            (0..n).map(|_| rng.next_u32() as u8).collect(),
        )
        .unwrap();
        svc.write(0, 0, 0, whole, &v).unwrap();
        Propagator::new(&svc).propagate_image().unwrap();
        let d = svc.read::<u8>(1, 0, 0, Box3::new([0, 0, 0], [64, 64, 16])).unwrap();
        // Spot check against direct mean.
        for &(x, y, z) in &[(0u64, 0u64, 0u64), (10, 20, 5), (63, 63, 15)] {
            let s = v.get([2 * x, 2 * y, z]) as u16
                + v.get([2 * x + 1, 2 * y, z]) as u16
                + v.get([2 * x, 2 * y + 1, z]) as u16
                + v.get([2 * x + 1, 2 * y + 1, z]) as u16;
            assert_eq!(d.get([x, y, z]), (s / 4) as u8);
        }
    }

    #[test]
    fn annotation_propagation_preserves_objects() {
        let svc = anno_service([256, 256, 32], 3);
        let bx = Box3::new([32, 32, 4], [96, 96, 12]);
        let mut v = DenseVolume::<u32>::zeros(bx.extent());
        v.fill_box(Box3::new([0, 0, 0], bx.extent()), 42);
        svc.write(0, 0, 0, bx, &v).unwrap();
        Propagator::new(&svc).propagate_annotations().unwrap();
        // At res 1 the object occupies the half-scale box.
        let out = svc.read::<u32>(1, 0, 0, Box3::new([16, 16, 4], [48, 48, 12])).unwrap();
        assert_eq!(out.count_eq(42), 32 * 32 * 8);
        // At res 2 quarter scale.
        let out = svc.read::<u32>(2, 0, 0, Box3::new([8, 8, 4], [24, 24, 12])).unwrap();
        assert_eq!(out.count_eq(42), 16 * 16 * 8);
    }

    #[test]
    fn lazy_propagation_skips_empty_space() {
        let svc = anno_service([512, 512, 32], 2);
        // One small object in a huge volume.
        let bx = Box3::new([0, 0, 0], [8, 8, 2]);
        let mut v = DenseVolume::<u32>::zeros(bx.extent());
        v.fill_box(Box3::new([0, 0, 0], bx.extent()), 7);
        svc.write(0, 0, 0, bx, &v).unwrap();
        Propagator::new(&svc).propagate_annotations().unwrap();
        // Level 1 must store at most a couple of cuboids.
        let stored = svc.store().stored_codes(1, 0).unwrap();
        assert!(stored.len() <= 2, "stored {} cuboids at level 1", stored.len());
    }
}
