//! 3-d connected components over a binary mask (6-connectivity) via
//! union-find — the detection-extraction step of the synapse pipeline.

use crate::array::DenseVolume;
use crate::core::Vec3;

/// One connected component of the mask.
#[derive(Clone, Debug)]
pub struct Component {
    /// Member voxels (local coordinates).
    pub voxels: Vec<Vec3>,
    /// Integer centroid (local coordinates).
    pub centroid: Vec3,
}

struct UnionFind {
    parent: Vec<u32>,
}

impl UnionFind {
    fn new(n: usize) -> Self {
        UnionFind { parent: (0..n as u32).collect() }
    }

    fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            let gp = self.parent[self.parent[x as usize] as usize];
            self.parent[x as usize] = gp; // path halving
            x = gp;
        }
        x
    }

    fn union(&mut self, a: u32, b: u32) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.parent[ra.max(rb) as usize] = ra.min(rb);
        }
    }
}

/// Extract 6-connected components of non-zero voxels.
pub fn connected_components(mask: &DenseVolume<u8>) -> Vec<Component> {
    let d = mask.dims();
    let n = mask.len();
    // Map voxel linear index -> dense component slot (only for set voxels).
    let mut slot = vec![u32::MAX; n];
    let mut count = 0u32;
    for i in 0..n {
        if mask.as_slice()[i] != 0 {
            slot[i] = count;
            count += 1;
        }
    }
    if count == 0 {
        return Vec::new();
    }
    let mut uf = UnionFind::new(count as usize);
    let idx = |x: u64, y: u64, z: u64| (x + d[0] * (y + d[1] * z)) as usize;
    for z in 0..d[2] {
        for y in 0..d[1] {
            for x in 0..d[0] {
                let i = idx(x, y, z);
                if slot[i] == u32::MAX {
                    continue;
                }
                // Only look backwards: -x, -y, -z (each pair united once).
                if x > 0 && slot[idx(x - 1, y, z)] != u32::MAX {
                    uf.union(slot[i], slot[idx(x - 1, y, z)]);
                }
                if y > 0 && slot[idx(x, y - 1, z)] != u32::MAX {
                    uf.union(slot[i], slot[idx(x, y - 1, z)]);
                }
                if z > 0 && slot[idx(x, y, z - 1)] != u32::MAX {
                    uf.union(slot[i], slot[idx(x, y, z - 1)]);
                }
            }
        }
    }
    // Gather members per root.
    let mut by_root: std::collections::HashMap<u32, Vec<Vec3>> =
        std::collections::HashMap::new();
    for z in 0..d[2] {
        for y in 0..d[1] {
            for x in 0..d[0] {
                let i = idx(x, y, z);
                if slot[i] != u32::MAX {
                    let root = uf.find(slot[i]);
                    by_root.entry(root).or_default().push([x, y, z]);
                }
            }
        }
    }
    let mut comps: Vec<Component> = by_root
        .into_values()
        .map(|voxels| {
            let n = voxels.len() as u64;
            let mut s = [0u64; 3];
            for v in &voxels {
                for a in 0..3 {
                    s[a] += v[a];
                }
            }
            Component { centroid: [s[0] / n, s[1] / n, s[2] / n], voxels }
        })
        .collect();
    comps.sort_by_key(|c| c.voxels[0]); // deterministic order
    comps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Box3;

    #[test]
    fn empty_mask_no_components() {
        let mask = DenseVolume::<u8>::zeros([8, 8, 4]);
        assert!(connected_components(&mask).is_empty());
    }

    #[test]
    fn single_blob() {
        let mut mask = DenseVolume::<u8>::zeros([16, 16, 4]);
        mask.fill_box(Box3::new([2, 2, 1], [6, 6, 3]), 1);
        let comps = connected_components(&mask);
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].voxels.len(), 4 * 4 * 2);
        assert_eq!(comps[0].centroid, [3, 3, 1]);
    }

    #[test]
    fn two_separate_blobs() {
        let mut mask = DenseVolume::<u8>::zeros([16, 16, 4]);
        mask.fill_box(Box3::new([0, 0, 0], [3, 3, 2]), 1);
        mask.fill_box(Box3::new([10, 10, 2], [13, 13, 4]), 1);
        let comps = connected_components(&mask);
        assert_eq!(comps.len(), 2);
        let sizes: Vec<usize> = comps.iter().map(|c| c.voxels.len()).collect();
        assert_eq!(sizes, vec![18, 18]);
    }

    #[test]
    fn diagonal_touch_is_not_connected() {
        // 6-connectivity: diagonal neighbours are separate components.
        let mut mask = DenseVolume::<u8>::zeros([4, 4, 1]);
        mask.set([0, 0, 0], 1);
        mask.set([1, 1, 0], 1);
        assert_eq!(connected_components(&mask).len(), 2);
    }

    #[test]
    fn l_shape_connected() {
        let mut mask = DenseVolume::<u8>::zeros([8, 8, 1]);
        mask.fill_box(Box3::new([0, 0, 0], [5, 1, 1]), 1);
        mask.fill_box(Box3::new([4, 0, 0], [5, 5, 1]), 1);
        let comps = connected_components(&mask);
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].voxels.len(), 9);
    }

    #[test]
    fn connected_across_z() {
        let mut mask = DenseVolume::<u8>::zeros([4, 4, 4]);
        for z in 0..4 {
            mask.set([2, 2, z], 1);
        }
        let comps = connected_components(&mask);
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].voxels.len(), 4);
    }
}
