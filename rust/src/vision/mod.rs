//! The parallel computer-vision workflows the cluster exists to feed
//! (§2, §4, Figure 7): synapse detection and color correction.
//!
//! The synapse pipeline is the paper's headline workload — "we ran 20
//! parallel instances and processed the entire [4 Tvox] volume in less
//! than 3 days", writing 19M synapses through the annotation Web services
//! with 40-object write batches. Here each worker:
//!
//! 1. cutouts one haloed block from the image project (read path → DB
//!    nodes),
//! 2. runs the AOT-compiled detector graph through PJRT (Layer 2/1),
//! 3. thresholds the probability map and extracts 3-d connected
//!    components,
//! 4. writes RAMON synapses + label voxels to the annotation project in
//!    batches (write path → SSD nodes).
//!
//! Components are extracted per block; a synapse whose blob straddles a
//! block boundary may be reported by both blocks (the paper's parallel
//! instances share the same property). Ground truth from the synthetic
//! generator lets us report precision/recall, which §2 could not.

mod components;

pub use components::{connected_components, Component};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::annotation::{AnnotationDb, RamonObject, SynapseType};
use crate::array::DenseVolume;
use crate::core::{Box3, Vec3, WriteDiscipline};
use crate::cutout::CutoutService;
use crate::runtime::{Runtime, DETECTOR_HALO, GRAPHS};
use crate::util::pool::scoped_map;
use crate::Result;

/// Synapse-detection pipeline configuration.
pub struct SynapsePipeline {
    pub runtime: Arc<Runtime>,
    pub image: Arc<CutoutService>,
    pub annotations: Arc<AnnotationDb>,
    /// Probability threshold for the detector output.
    pub threshold: f32,
    /// Component size filter (voxels): rejects speckle and large masses
    /// (vessels, cell bodies — §3.1's masking step).
    pub min_voxels: usize,
    pub max_voxels: usize,
    /// RAMON objects per metadata write batch (§4.2: 40 doubled
    /// throughput).
    pub write_batch: usize,
    /// Parallel workers ("parallel instances" in §2).
    pub workers: usize,
    /// Mask detections inside large bright structures (blood vessels,
    /// cell bodies) — the paper's false-positive masking stage (§3.1:
    /// "We analyze large structures that cannot contain synapses ... to
    /// mask out false positives").
    pub mask_bright_structures: bool,
    /// Local-mean gray level above which a region counts as a large
    /// bright structure.
    pub mask_level: f32,
    /// Box radius (x, y, z) of the local-mean window for masking.
    pub mask_radius: [u64; 3],
}

/// One detected synapse.
#[derive(Clone, Debug)]
pub struct Detection {
    pub id: u32,
    pub centroid: Vec3,
    pub voxels: usize,
    pub confidence: f32,
}

/// Pipeline run report.
#[derive(Clone, Debug, Default)]
pub struct PipelineReport {
    pub blocks: u64,
    pub detections: Vec<Detection>,
    pub voxels_read: u64,
    pub voxels_labeled: u64,
    pub wall_secs: f64,
    /// Cutout bytes fetched per second (read side).
    pub read_mbps: f64,
    /// RAMON objects written per second (write side).
    pub objects_per_sec: f64,
}

impl SynapsePipeline {
    pub fn new(
        runtime: Arc<Runtime>,
        image: Arc<CutoutService>,
        annotations: Arc<AnnotationDb>,
    ) -> Self {
        SynapsePipeline {
            runtime,
            image,
            annotations,
            threshold: 0.8,
            min_voxels: 4,
            max_voxels: 400,
            write_batch: 40,
            workers: 4,
            mask_bright_structures: true,
            mask_level: 132.0,
            mask_radius: [8, 8, 2],
        }
    }

    /// The detector-core block tiling of `region` at `res` — clipped
    /// core boxes in deterministic z-major order. This is the shared
    /// block plan of [`SynapsePipeline::run`] and the batch job engine's
    /// [`crate::jobs::SynapseDetectJob`], so the two execute the exact
    /// same block set.
    pub fn core_blocks(&self, res: u32, region: Box3) -> Result<Vec<Box3>> {
        let spec = GRAPHS[0]; // synapse_detector
        let core = [spec.output[0] as u64, spec.output[1] as u64, spec.output[2] as u64];
        let bounds = self.image.store().dataset.level(res)?.bounds();
        let region = region.intersect(&bounds);
        let mut blocks = Vec::new();
        let mut z = region.lo[2];
        while z < region.hi[2] {
            let mut y = region.lo[1];
            while y < region.hi[1] {
                let mut x = region.lo[0];
                while x < region.hi[0] {
                    blocks.push(Box3::new(
                        [x, y, z],
                        [
                            (x + core[0]).min(region.hi[0]),
                            (y + core[1]).min(region.hi[1]),
                            (z + core[2]).min(region.hi[2]),
                        ],
                    ));
                    x += core[0];
                }
                y += core[1];
            }
            z += core[2];
        }
        Ok(blocks)
    }

    /// Enrich detections with position/author metadata and write them in
    /// `write_batch`-sized RAMON batches (§4.2's batch interface).
    fn write_metadata(&self, dets: &[Detection]) -> Result<()> {
        for chunk in dets.chunks(self.write_batch.max(1)) {
            let objs: Vec<RamonObject> = chunk
                .iter()
                .map(|d| {
                    let mut o = RamonObject::synapse(d.id, d.confidence, SynapseType::Unknown);
                    o.seeds = vec![];
                    o.position = d.centroid;
                    o.author = "ocpd-synapse-pipeline".into();
                    o
                })
                .collect();
            self.annotations.put_objects(objs)?;
        }
        Ok(())
    }

    /// Detect in one core block and write labels + batched RAMON
    /// metadata — the batch job engine's per-block unit
    /// ([`crate::jobs::SynapseDetectJob`]). Re-execution safe: any
    /// failure deletes the objects this attempt created before
    /// returning, so the job engine's retries (and a checkpoint-resume
    /// re-run of an unjournaled block) never duplicate synapses.
    pub fn detect_block(&self, res: u32, core_box: Box3) -> Result<Vec<Detection>> {
        let voxels = AtomicU64::new(0);
        let dets = self.process_block(res, core_box.lo, core_box, &voxels)?;
        if !dets.is_empty() {
            if let Err(e) = self.write_metadata(&dets) {
                for d in &dets {
                    let _ = self.annotations.delete_object(res, d.id);
                }
                return Err(e);
            }
        }
        Ok(dets)
    }

    /// Run detection over `region` at resolution `res`. The region is
    /// tiled into detector-core-sized blocks.
    pub fn run(&self, res: u32, region: Box3) -> Result<PipelineReport> {
        let blocks = self.core_blocks(res, region)?;

        let t0 = Instant::now();
        let voxels_read = AtomicU64::new(0);
        let voxels_labeled = AtomicU64::new(0);
        let detections: Mutex<Vec<Detection>> = Mutex::new(Vec::new());

        let results = scoped_map(blocks.len(), self.workers, |i| -> Result<()> {
            let core_box = blocks[i];
            let dets = self.process_block(res, core_box.lo, core_box, &voxels_read)?;
            if dets.is_empty() {
                return Ok(());
            }
            // Batched writes: metadata in write_batch groups, voxels as
            // one label volume per block.
            self.write_metadata(&dets)?;
            voxels_labeled
                .fetch_add(dets.iter().map(|d| d.voxels as u64).sum(), Ordering::Relaxed);
            detections.lock().unwrap().extend(dets);
            Ok(())
        });
        for r in results {
            r?;
        }

        let wall = t0.elapsed().as_secs_f64();
        let mut dets = detections.into_inner().unwrap();
        dets.sort_by_key(|d| d.id);
        let report = PipelineReport {
            blocks: blocks.len() as u64,
            voxels_read: voxels_read.load(Ordering::Relaxed),
            voxels_labeled: voxels_labeled.load(Ordering::Relaxed),
            wall_secs: wall,
            read_mbps: voxels_read.load(Ordering::Relaxed) as f64 / 1e6 / wall.max(1e-9),
            objects_per_sec: dets.len() as f64 / wall.max(1e-9),
            detections: dets,
        };
        Ok(report)
    }

    /// Detect in one core block: haloed cutout -> PJRT -> threshold ->
    /// components -> label write.
    fn process_block(
        &self,
        res: u32,
        block_lo: Vec3,
        core_box: Box3,
        voxels_read: &AtomicU64,
    ) -> Result<Vec<Detection>> {
        let spec = GRAPHS[0];
        let bounds = self.image.store().dataset.level(res)?.bounds();
        let halo = DETECTOR_HALO;
        let in_dims = [spec.input[0] as u64, spec.input[1] as u64, spec.input[2] as u64];

        // Haloed box, clamped to volume bounds; out-of-bounds stays zero.
        let want = Box3::new(
            [
                block_lo[0].saturating_sub(halo[0]),
                block_lo[1].saturating_sub(halo[1]),
                block_lo[2].saturating_sub(halo[2]),
            ],
            [
                (block_lo[0] + in_dims[0] - halo[0]).min(bounds.hi[0]),
                (block_lo[1] + in_dims[1] - halo[1]).min(bounds.hi[1]),
                (block_lo[2] + in_dims[2] - halo[2]).min(bounds.hi[2]),
            ],
        );
        let img = self.image.read::<u8>(res, 0, 0, want)?;
        voxels_read.fetch_add(img.len() as u64, Ordering::Relaxed);

        // Assemble the fixed-shape f32 input: normalized to [0,1],
        // positioned so the core lands at `halo`. Outside the volume the
        // halo is filled by edge replication — zero padding would create
        // a step edge that the DoG detects as a border ring of false
        // positives.
        let mut input = DenseVolume::<f32>::zeros(in_dims);
        let off = [
            halo[0] - (block_lo[0] - want.lo[0]),
            halo[1] - (block_lo[1] - want.lo[1]),
            halo[2] - (block_lo[2] - want.lo[2]),
        ];
        let id_ = img.dims();
        for z in 0..in_dims[2] {
            let sz = z.saturating_sub(off[2]).min(id_[2] - 1);
            for y in 0..in_dims[1] {
                let sy = y.saturating_sub(off[1]).min(id_[1] - 1);
                for x in 0..in_dims[0] {
                    let sx = x.saturating_sub(off[0]).min(id_[0] - 1);
                    input.set([x, y, z], img.get([sx, sy, sz]) as f32 / 255.0);
                }
            }
        }

        let prob = self.runtime.run3d("synapse_detector", &input)?;

        // Threshold into a mask restricted to the (possibly clipped) core.
        let core_ext = core_box.extent();
        let mut mask = DenseVolume::<u8>::zeros(core_ext);
        for z in 0..core_ext[2] {
            for y in 0..core_ext[1] {
                for x in 0..core_ext[0] {
                    if prob.get([x, y, z]) >= self.threshold {
                        mask.set([x, y, z], 1);
                    }
                }
            }
        }

        // Large-bright-structure mask (§3.1): local mean brightness via
        // an integral image over the haloed input; detections whose
        // centroid sits in a bright mass (vessel / cell body) are
        // rejected.
        let bright = if self.mask_bright_structures {
            Some(LocalMean::new(&input))
        } else {
            None
        };
        // Core voxel [v] sits at input index [v + halo].
        let core_off = halo;

        // Filter components first (pure compute, nothing allocated).
        let comps = connected_components(&mask);
        let mut kept: Vec<(Component, f32)> = Vec::new();
        for comp in comps {
            if comp.voxels.len() < self.min_voxels || comp.voxels.len() > self.max_voxels {
                continue;
            }
            if let Some(bright) = &bright {
                let p = [
                    comp.centroid[0] + core_off[0],
                    comp.centroid[1] + core_off[1],
                    comp.centroid[2] + core_off[2],
                ];
                if bright.mean(p, self.mask_radius) * 255.0 > self.mask_level {
                    continue; // inside a vessel / cell body
                }
            }
            // Confidence: mean probability over the component.
            let mean_p = comp
                .voxels
                .iter()
                .map(|&v| prob.get(v))
                .sum::<f32>()
                / comp.voxels.len() as f32;
            kept.push((comp, mean_p));
        }

        // Allocate ids + write labels in a compensated section: on a
        // partial failure, delete everything this attempt created, so a
        // retry (or a checkpoint-resume re-execution) of the block
        // cannot leave duplicate synapse objects behind.
        let mut dets = Vec::new();
        let mut labels = DenseVolume::<u32>::zeros(core_ext);
        let attempt = (|| -> Result<()> {
            for (comp, mean_p) in &kept {
                let id = self.annotations.put_object(RamonObject::synapse(
                    0,
                    *mean_p,
                    SynapseType::Unknown,
                ))?;
                for &v in &comp.voxels {
                    labels.set(v, id);
                }
                dets.push(Detection {
                    id,
                    centroid: [
                        core_box.lo[0] + comp.centroid[0],
                        core_box.lo[1] + comp.centroid[1],
                        core_box.lo[2] + comp.centroid[2],
                    ],
                    voxels: comp.voxels.len(),
                    confidence: *mean_p,
                });
            }
            if !dets.is_empty() {
                self.annotations.write_volume(
                    res,
                    core_box,
                    &labels,
                    WriteDiscipline::Preserve,
                )?;
            }
            Ok(())
        })();
        if let Err(e) = attempt {
            for d in &dets {
                let _ = self.annotations.delete_object(res, d.id);
            }
            return Err(e);
        }
        Ok(dets)
    }
}

/// 3-d integral image over an f32 volume: O(1) box-mean queries (the
/// summed-area tables of Crow [7], which the paper cites for exactly this
/// kind of data-parallel filtering).
struct LocalMean {
    dims: Vec3,
    /// Prefix sums with a one-voxel zero border: sums[x][y][z] = sum of
    /// all voxels with coords < (x, y, z).
    sums: Vec<f64>,
}

impl LocalMean {
    fn new(vol: &DenseVolume<f32>) -> LocalMean {
        let d = vol.dims();
        let (sx, sy, sz) = (d[0] as usize + 1, d[1] as usize + 1, d[2] as usize + 1);
        let mut sums = vec![0f64; sx * sy * sz];
        let idx = |x: usize, y: usize, z: usize| x + sx * (y + sy * z);
        for z in 1..sz {
            for y in 1..sy {
                let mut row = 0f64;
                for x in 1..sx {
                    row += vol.get([(x - 1) as u64, (y - 1) as u64, (z - 1) as u64]) as f64;
                    sums[idx(x, y, z)] =
                        row + sums[idx(x, y, z - 1)] + sums[idx(x, y - 1, z)]
                            - sums[idx(x, y - 1, z - 1)];
                }
            }
        }
        LocalMean { dims: d, sums }
    }

    /// Mean over the box `center ± radius`, clipped to the volume.
    fn mean(&self, center: Vec3, radius: [u64; 3]) -> f32 {
        let lo = [
            center[0].saturating_sub(radius[0]) as usize,
            center[1].saturating_sub(radius[1]) as usize,
            center[2].saturating_sub(radius[2]) as usize,
        ];
        let hi = [
            (center[0] + radius[0] + 1).min(self.dims[0]) as usize,
            (center[1] + radius[1] + 1).min(self.dims[1]) as usize,
            (center[2] + radius[2] + 1).min(self.dims[2]) as usize,
        ];
        let (sx, sy) = (self.dims[0] as usize + 1, self.dims[1] as usize + 1);
        let s = |x: usize, y: usize, z: usize| self.sums[x + sx * (y + sy * z)];
        let total = s(hi[0], hi[1], hi[2]) - s(lo[0], hi[1], hi[2]) - s(hi[0], lo[1], hi[2])
            - s(hi[0], hi[1], lo[2])
            + s(lo[0], lo[1], hi[2])
            + s(lo[0], hi[1], lo[2])
            + s(hi[0], lo[1], lo[2])
            - s(lo[0], lo[1], lo[2]);
        let n = (hi[0] - lo[0]) * (hi[1] - lo[1]) * (hi[2] - lo[2]);
        (total / n.max(1) as f64) as f32
    }
}

/// Match detections against ground-truth centroids within `radius`
/// voxels (greedy, nearest-first): returns (precision, recall, matches).
pub fn precision_recall(
    detections: &[Detection],
    truth: &[Vec3],
    radius: f64,
) -> (f64, f64, usize) {
    if detections.is_empty() || truth.is_empty() {
        return (0.0, 0.0, 0);
    }
    let mut pairs = Vec::new();
    for (di, d) in detections.iter().enumerate() {
        for (ti, t) in truth.iter().enumerate() {
            let dx = d.centroid[0] as f64 - t[0] as f64;
            let dy = d.centroid[1] as f64 - t[1] as f64;
            let dz = (d.centroid[2] as f64 - t[2] as f64) * 2.0; // anisotropy
            let dist = (dx * dx + dy * dy + dz * dz).sqrt();
            if dist <= radius {
                pairs.push((dist as f32, di, ti));
            }
        }
    }
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut used_d = vec![false; detections.len()];
    let mut used_t = vec![false; truth.len()];
    let mut matches = 0;
    for (_, di, ti) in pairs {
        if !used_d[di] && !used_t[ti] {
            used_d[di] = true;
            used_t[ti] = true;
            matches += 1;
        }
    }
    (
        matches as f64 / detections.len() as f64,
        matches as f64 / truth.len() as f64,
        matches,
    )
}

/// Color-correction driver (§3.4): stream `color_correct`-shaped blocks
/// from a source image project through the AOT graph into a destination
/// ("cleaned") project. Returns blocks processed.
pub fn color_correct_volume(
    runtime: &Runtime,
    src: &CutoutService,
    dst: &CutoutService,
    res: u32,
) -> Result<u64> {
    let spec = GRAPHS[1];
    let shape = [spec.input[0] as u64, spec.input[1] as u64, spec.input[2] as u64];
    let dims = src.store().dataset.level(res)?.dims;
    let mut blocks = 0;
    let mut z = 0;
    while z < dims[2] {
        let mut y = 0;
        while y < dims[1] {
            let mut x = 0;
            while x < dims[0] {
                let bx = Box3::new(
                    [x, y, z],
                    [(x + shape[0]).min(dims[0]), (y + shape[1]).min(dims[1]), (z + shape[2]).min(dims[2])],
                );
                // Fixed-shape graph: pad clipped edge blocks with edge
                // replication would be ideal; zero-pad is fine since the
                // high-frequency add-back cancels the bias inside the
                // valid region.
                let img = src.read::<u8>(res, 0, 0, bx)?;
                let mut input = DenseVolume::<f32>::zeros(shape);
                let e = bx.extent();
                for zz in 0..e[2] {
                    for yy in 0..e[1] {
                        for xx in 0..e[0] {
                            input.set([xx, yy, zz], img.get([xx, yy, zz]) as f32 / 255.0);
                        }
                    }
                }
                let out = runtime.run3d("color_correct", &input)?;
                let mut corrected = DenseVolume::<u8>::zeros(e);
                for zz in 0..e[2] {
                    for yy in 0..e[1] {
                        for xx in 0..e[0] {
                            corrected.set(
                                [xx, yy, zz],
                                (out.get([xx, yy, zz]) * 255.0).clamp(0.0, 255.0) as u8,
                            );
                        }
                    }
                }
                dst.write(res, 0, 0, bx, &corrected)?;
                blocks += 1;
                x += shape[0];
            }
            y += shape[1];
        }
        z += shape[2];
    }
    Ok(blocks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det(id: u32, c: Vec3) -> Detection {
        Detection { id, centroid: c, voxels: 10, confidence: 0.9 }
    }

    #[test]
    fn precision_recall_perfect() {
        let truth = vec![[10u64, 10, 5], [50, 50, 8]];
        let dets = vec![det(1, [10, 11, 5]), det(2, [49, 50, 8])];
        let (p, r, m) = precision_recall(&dets, &truth, 5.0);
        assert_eq!(m, 2);
        assert_eq!(p, 1.0);
        assert_eq!(r, 1.0);
    }

    #[test]
    fn precision_recall_partial() {
        let truth = vec![[10u64, 10, 5], [50, 50, 8], [90, 90, 2]];
        let dets = vec![det(1, [10, 10, 5]), det(2, [200, 200, 10])];
        let (p, r, m) = precision_recall(&dets, &truth, 5.0);
        assert_eq!(m, 1);
        assert!((p - 0.5).abs() < 1e-9);
        assert!((r - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn precision_recall_no_double_matching() {
        // Two detections near one truth point: only one may match.
        let truth = vec![[10u64, 10, 5]];
        let dets = vec![det(1, [10, 10, 5]), det(2, [11, 10, 5])];
        let (_, r, m) = precision_recall(&dets, &truth, 5.0);
        assert_eq!(m, 1);
        assert_eq!(r, 1.0);
    }

    #[test]
    fn precision_recall_empty() {
        assert_eq!(precision_recall(&[], &[[1, 1, 1]], 5.0).2, 0);
        assert_eq!(precision_recall(&[det(1, [1, 1, 1])], &[], 5.0).2, 0);
    }
}
