//! Weighted fair worker-pool gates.
//!
//! The engines don't keep standing worker pools — each request fans its
//! batch list over scoped threads ([`crate::util::pool::scoped_map`]).
//! FIFO fairness therefore can't be fixed by reordering a queue that
//! doesn't exist; instead every worker acquires a **slot** from the
//! pool's [`FairGate`] *per batch*, and the gate decides who runs next
//! whenever a slot frees. Because batches are short and slots are
//! re-acquired at every batch boundary, a deep queue from one tenant
//! interleaves with everyone else at batch granularity — the same
//! effect as deficit-round-robin over the batch lists, without
//! restructuring the engines.
//!
//! Grant order is **priority, then weighted virtual time, then FIFO**:
//!
//! * a waiter of a higher [`RouteClass`] always runs before a lower one
//!   (interactive > status > bulk) — this is what lets interactive
//!   cutouts overtake a bulk storm inside the same pool;
//! * within a class, each tenant carries a virtual clock advanced by
//!   `QUANTUM / weight` per granted slot (stride scheduling): a tenant
//!   with weight 2 accrues half the virtual time per slot and therefore
//!   receives twice the slots under contention. New tenants start at
//!   the gate's global virtual clock, so idling never banks credit;
//! * ties break by arrival order.
//!
//! When enforcement is disabled the gate is a single relaxed atomic
//! load — the engines pay nothing.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::metrics::Histogram;
use crate::obs::slo::RouteClass;

/// Virtual-time quantum charged per granted slot at weight 1.
const QUANTUM: u64 = 1_000_000;

/// Scheduling rank of a class: lower runs first.
fn rank(class: RouteClass) -> u8 {
    match class {
        RouteClass::Interactive => 0,
        RouteClass::Status => 1,
        RouteClass::Bulk => 2,
    }
}

struct Waiter {
    ticket: u64,
    rank: u8,
    tenant: Option<Arc<str>>,
    weight: u64,
    enqueued: Instant,
}

struct GateState {
    active: usize,
    next_ticket: u64,
    waiters: Vec<Waiter>,
    /// Per-tenant virtual clocks; `None`-tenant work runs under the
    /// shared anonymous clock.
    vtime: HashMap<Arc<str>, u64>,
    anon_vtime: u64,
    /// Global virtual clock: the vtime charged at the last grant. New
    /// tenants start here so idling never banks credit.
    global_vtime: u64,
}

/// One worker pool's admission gate. See the module docs for the grant
/// discipline.
pub struct FairGate {
    name: &'static str,
    capacity: usize,
    enabled: Arc<AtomicBool>,
    state: Mutex<GateState>,
    cv: Condvar,
    /// Queue-wait per class (indexed by [`rank`]): interactive, status,
    /// bulk. Rendered as `ocpd_qos_queue_wait_us`.
    wait_hists: [Arc<Histogram>; 3],
    granted: [Arc<crate::metrics::Counter>; 3],
}

impl FairGate {
    /// A gate of `capacity` slots, active only while `enabled` is true
    /// (the flag is shared with the owning enforcer).
    pub fn new(name: &'static str, capacity: usize, enabled: Arc<AtomicBool>) -> Self {
        FairGate {
            name,
            capacity: capacity.max(1),
            enabled,
            state: Mutex::new(GateState {
                active: 0,
                next_ticket: 0,
                waiters: Vec::new(),
                vtime: HashMap::new(),
                anon_vtime: 0,
                global_vtime: 0,
            }),
            cv: Condvar::new(),
            wait_hists: std::array::from_fn(|_| Arc::new(Histogram::new())),
            granted: std::array::from_fn(|_| Arc::new(crate::metrics::Counter::default())),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Queue-wait histogram for `class`.
    pub fn wait_hist(&self, class: RouteClass) -> Arc<Histogram> {
        self.wait_hists[rank(class) as usize].clone()
    }

    /// Slots granted to `class` so far.
    pub fn granted(&self, class: RouteClass) -> u64 {
        self.granted[rank(class) as usize].get()
    }

    /// Currently queued waiters (status surface).
    pub fn waiting(&self) -> usize {
        self.state.lock().unwrap().waiters.len()
    }

    /// Forget a retired tenant's virtual clock.
    pub fn retire_tenant(&self, tenant: &str) {
        self.state.lock().unwrap().vtime.remove(tenant);
    }

    fn vtime_of(st: &GateState, w: &Waiter) -> u64 {
        match &w.tenant {
            Some(t) => st.vtime.get(t).copied().unwrap_or(st.global_vtime),
            None => st.anon_vtime.max(st.global_vtime),
        }
    }

    /// Is `ticket` the waiter the gate would grant next?
    fn is_next(st: &GateState, ticket: u64) -> bool {
        let best = st
            .waiters
            .iter()
            .min_by_key(|w| (w.rank, Self::vtime_of(st, w), w.ticket))
            .map(|w| w.ticket);
        best == Some(ticket)
    }

    /// Acquire a slot for one batch of work. Blocks until granted;
    /// release happens when the returned guard drops. A disabled gate
    /// returns immediately.
    pub fn acquire(
        &self,
        class: RouteClass,
        tenant: Option<Arc<str>>,
        weight: u64,
    ) -> GateGuard<'_> {
        if !self.enabled.load(Ordering::Relaxed) {
            return GateGuard { gate: None };
        }
        let mut st = self.state.lock().unwrap();
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        st.waiters.push(Waiter {
            ticket,
            rank: rank(class),
            tenant,
            weight: weight.max(1),
            enqueued: Instant::now(),
        });
        loop {
            if st.active < self.capacity && Self::is_next(&st, ticket) {
                let idx = st.waiters.iter().position(|w| w.ticket == ticket).unwrap();
                let w = st.waiters.swap_remove(idx);
                st.active += 1;
                let charge = QUANTUM / w.weight;
                let vt = match &w.tenant {
                    Some(t) => {
                        let base = st.global_vtime;
                        let vt = st.vtime.entry(t.clone()).or_insert(base);
                        *vt += charge;
                        *vt
                    }
                    None => {
                        st.anon_vtime = st.anon_vtime.max(st.global_vtime) + charge;
                        st.anon_vtime
                    }
                };
                st.global_vtime = st.global_vtime.max(vt.saturating_sub(charge));
                self.wait_hists[w.rank as usize].record(w.enqueued.elapsed());
                self.granted[w.rank as usize].inc();
                // A slot may still be free for the *next*-best waiter,
                // who went to sleep when it lost this evaluation — wake
                // the queue so it re-checks.
                let wake = st.active < self.capacity && !st.waiters.is_empty();
                drop(st);
                if wake {
                    self.cv.notify_all();
                }
                return GateGuard { gate: Some(self) };
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// A no-op guard — the enforcer's disabled fast path, skipping even
    /// the enabled-flag load [`FairGate::acquire`] would pay.
    pub(crate) fn acquire_disabled(&self) -> GateGuard<'_> {
        GateGuard { gate: None }
    }

    fn release(&self) {
        let mut st = self.state.lock().unwrap();
        st.active = st.active.saturating_sub(1);
        drop(st);
        self.cv.notify_all();
    }
}

/// Releases the slot (and wakes the next waiter) on drop. Guards from a
/// disabled gate hold nothing.
pub struct GateGuard<'a> {
    gate: Option<&'a FairGate>,
}

impl Drop for GateGuard<'_> {
    fn drop(&mut self) {
        if let Some(g) = self.gate {
            g.release();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn gate(capacity: usize) -> FairGate {
        FairGate::new("test", capacity, Arc::new(AtomicBool::new(true)))
    }

    #[test]
    fn disabled_gate_is_free() {
        let g = FairGate::new("off", 1, Arc::new(AtomicBool::new(false)));
        // Capacity 1, but both "slots" grant instantly: no accounting.
        let a = g.acquire(RouteClass::Bulk, None, 1);
        let b = g.acquire(RouteClass::Bulk, None, 1);
        assert_eq!(g.granted(RouteClass::Bulk), 0);
        drop((a, b));
    }

    #[test]
    fn capacity_bounds_concurrency() {
        let g = Arc::new(gate(2));
        let peak = Arc::new(AtomicUsize::new(0));
        let live = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..8 {
                let (g, peak, live) = (g.clone(), peak.clone(), live.clone());
                s.spawn(move || {
                    for _ in 0..20 {
                        let _slot = g.acquire(RouteClass::Bulk, None, 1);
                        let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                        peak.fetch_max(now, Ordering::SeqCst);
                        std::thread::sleep(std::time::Duration::from_micros(200));
                        live.fetch_sub(1, Ordering::SeqCst);
                    }
                });
            }
        });
        assert!(peak.load(Ordering::SeqCst) <= 2, "peak {}", peak.load(Ordering::SeqCst));
        assert_eq!(g.granted(RouteClass::Bulk), 160);
    }

    #[test]
    fn interactive_overtakes_queued_bulk() {
        let g = Arc::new(gate(1));
        let hold = g.acquire(RouteClass::Bulk, None, 1);
        let order = Arc::new(Mutex::new(Vec::new()));
        std::thread::scope(|s| {
            // Bulk waiter enqueues first...
            let (g2, o2) = (g.clone(), order.clone());
            s.spawn(move || {
                let _s = g2.acquire(RouteClass::Bulk, Some("bulk".into()), 1);
                o2.lock().unwrap().push("bulk");
            });
            std::thread::sleep(std::time::Duration::from_millis(20));
            // ...but the interactive waiter lands the freed slot.
            let (g3, o3) = (g.clone(), order.clone());
            s.spawn(move || {
                let _s = g3.acquire(RouteClass::Interactive, Some("ia".into()), 1);
                o3.lock().unwrap().push("interactive");
            });
            std::thread::sleep(std::time::Duration::from_millis(20));
            drop(hold);
        });
        assert_eq!(*order.lock().unwrap(), vec!["interactive", "bulk"]);
    }

    #[test]
    fn weights_split_slots_proportionally() {
        let g = Arc::new(gate(1));
        let heavy: Arc<str> = "heavy".into();
        let light: Arc<str> = "light".into();
        let heavy_done = Arc::new(AtomicUsize::new(0));
        let light_done = Arc::new(AtomicUsize::new(0));
        // Two saturating tenants, weight 3 vs 1, same class: after the
        // same wall-clock of contention, grants split ~3:1.
        let stop = Arc::new(AtomicBool::new(false));
        std::thread::scope(|s| {
            for (who, done, w) in [
                (heavy.clone(), heavy_done.clone(), 3u64),
                (light.clone(), light_done.clone(), 1u64),
            ] {
                let (g, stop) = (g.clone(), stop.clone());
                s.spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let _slot = g.acquire(RouteClass::Bulk, Some(who.clone()), w);
                        std::thread::sleep(std::time::Duration::from_micros(300));
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
            std::thread::sleep(std::time::Duration::from_millis(150));
            stop.store(true, Ordering::Relaxed);
        });
        let h = heavy_done.load(Ordering::Relaxed) as f64;
        let l = light_done.load(Ordering::Relaxed) as f64;
        assert!(l > 0.0, "light tenant starved outright");
        let ratio = h / l;
        assert!(ratio > 1.8 && ratio < 5.0, "weight-3 vs weight-1 split off: {ratio:.2}");
    }

    #[test]
    fn queue_wait_is_recorded_per_class() {
        let g = gate(1);
        drop(g.acquire(RouteClass::Interactive, None, 1));
        assert_eq!(g.wait_hist(RouteClass::Interactive).count(), 1);
        assert_eq!(g.wait_hist(RouteClass::Bulk).count(), 0);
    }
}
