//! Multi-tenant QoS enforcement: token-bucket admission, weighted fair
//! worker scheduling, and priority preemption.
//!
//! PR 8 built the *measurement* half of multi-tenancy — per-tenant
//! ledgers ([`crate::obs::account`]), per-class latency SLOs
//! ([`crate::obs::slo`]) — and this module is the *enforcement* half:
//! the cluster must degrade gracefully under overload instead of
//! letting one bulk-ingest storm starve every interactive reader. It
//! acts at three points:
//!
//! 1. **Admission** ([`QosEnforcer::admit`], called by the service
//!    dispatcher before routing): per-tenant token buckets — one in
//!    requests/s, one in bytes/s, refilled from configured
//!    [`Quota`]s — deny over-quota requests with `429` and a
//!    `Retry-After` computed from the bucket's actual refill time.
//!    A global overload guard sheds lowest-priority work with `503`
//!    when in-flight request bytes cross a high-water mark (bulk
//!    first, then status; interactive is never shed).
//! 2. **Worker pools** ([`fair::FairGate`]): the cutout read engine,
//!    the parallel write engine, and the job engine acquire a gate
//!    slot per batch/block, granted priority-then-weighted-fair, so a
//!    greedy tenant's deep batch list interleaves with everyone else.
//! 3. **Preemption** ([`QosEnforcer::yield_to_interactive`]): job
//!    workers pause at block boundaries while interactive requests are
//!    in flight — jobs checkpoint per block, so preemption costs
//!    nothing but the wait.
//!
//! Identity and deadline ride a thread-local [`ctx`], installed at
//! admission and propagated to fork-join workers by `scoped_map`.
//! Everything is off by default ([`QosEnforcer::enabled`] = false):
//! with enforcement off the only cost anywhere is one atomic load.

pub mod ctx;
pub mod fair;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::metrics::Counter;
use crate::obs::registry::Sample;
use crate::obs::slo::RouteClass;

pub use fair::{FairGate, GateGuard};

/// Default global high-water mark for in-flight request bytes (the
/// overload-shed trigger): 256 MiB.
pub const DEFAULT_HIGH_WATER_BYTES: u64 = 256 << 20;

/// Per-tenant rate and share configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Quota {
    /// Sustained admitted requests per second (burst = one second).
    pub req_per_s: f64,
    /// Sustained admitted request-payload bytes per second (burst = one
    /// second).
    pub bytes_per_s: f64,
    /// Fair-share weight inside the worker-pool gates (default 1; a
    /// weight-2 tenant receives twice the slots under contention).
    pub weight: u64,
}

impl Default for Quota {
    fn default() -> Self {
        Quota { req_per_s: f64::INFINITY, bytes_per_s: f64::INFINITY, weight: 1 }
    }
}

/// A token bucket: `rate` units/s refill toward a `burst` cap. On
/// denial, reports how long until the requested tokens exist.
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    state: Mutex<(f64, Instant)>,
}

impl TokenBucket {
    pub fn new(rate: f64, burst: f64) -> Self {
        TokenBucket { rate, burst, state: Mutex::new((burst, Instant::now())) }
    }

    fn refill(level: &mut f64, last: &mut Instant, rate: f64, burst: f64) {
        let now = Instant::now();
        if rate.is_finite() {
            *level = (*level + now.duration_since(*last).as_secs_f64() * rate).min(burst);
        } else {
            *level = burst;
        }
        *last = now;
    }

    /// Take `n` tokens, or report the wait until `n` would be
    /// available (the `Retry-After` source). Denials consume nothing.
    pub fn try_take(&self, n: f64) -> std::result::Result<(), Duration> {
        let mut st = self.state.lock().unwrap();
        let (level, last) = &mut *st;
        Self::refill(level, last, self.rate, self.burst);
        if *level >= n {
            *level -= n;
            Ok(())
        } else {
            let deficit = n - *level;
            Err(Duration::from_secs_f64(deficit / self.rate.max(1e-9)))
        }
    }

    /// Return `n` tokens (undo a take whose sibling check failed).
    pub fn give(&self, n: f64) {
        let mut st = self.state.lock().unwrap();
        st.0 = (st.0 + n).min(self.burst);
    }

    /// Current token level (refreshed), for the status surface.
    pub fn level(&self) -> f64 {
        let mut st = self.state.lock().unwrap();
        let (level, last) = &mut *st;
        Self::refill(level, last, self.rate, self.burst);
        *level
    }
}

/// Live enforcement state for one quota'd tenant.
struct TenantQos {
    quota: Quota,
    req: TokenBucket,
    bytes: TokenBucket,
    throttled: Counter,
}

impl TenantQos {
    fn new(quota: Quota) -> Self {
        TenantQos {
            quota,
            // Burst capacity: one second of the sustained rate (at
            // least one request / 64 KiB so a fresh bucket admits
            // *something*).
            req: TokenBucket::new(quota.req_per_s, quota.req_per_s.max(1.0)),
            bytes: TokenBucket::new(quota.bytes_per_s, quota.bytes_per_s.max(65_536.0)),
            throttled: Counter::default(),
        }
    }
}

/// Why admission refused a request.
#[derive(Debug)]
pub enum Denial {
    /// Per-tenant quota exhausted → `429 Too Many Requests`.
    Throttled { tenant: String, retry_after: Duration },
    /// Global overload shed → `503 Service Unavailable`.
    Shed { class: RouteClass, retry_after: Duration },
}

impl Denial {
    /// HTTP status this denial maps to.
    pub fn http_status(&self) -> u16 {
        match self {
            Denial::Throttled { .. } => 429,
            Denial::Shed { .. } => 503,
        }
    }

    /// `Retry-After` in whole seconds (ceiling, minimum 1).
    pub fn retry_after_secs(&self) -> u64 {
        let d = match self {
            Denial::Throttled { retry_after, .. } | Denial::Shed { retry_after, .. } => {
                *retry_after
            }
        };
        (d.as_secs_f64().ceil() as u64).max(1)
    }

    pub fn message(&self) -> String {
        match self {
            Denial::Throttled { tenant, retry_after } => format!(
                "tenant {tenant} over quota; retry after {:.3}s",
                retry_after.as_secs_f64()
            ),
            Denial::Shed { class, .. } => {
                format!("overloaded: {} work shed at the admission gate", class.name())
            }
        }
    }
}

/// Pool identifiers for the three fair gates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pool {
    Read,
    Write,
    Job,
}

impl Pool {
    pub fn name(self) -> &'static str {
        match self {
            Pool::Read => "read",
            Pool::Write => "write",
            Pool::Job => "job",
        }
    }
}

/// The cluster-wide QoS enforcer: quota table, token buckets, overload
/// guard, fair gates, and the counters behind `ocpd_qos_*`.
pub struct QosEnforcer {
    enabled: Arc<AtomicBool>,
    tenants: RwLock<HashMap<String, Arc<TenantQos>>>,
    /// Sum of admitted request-payload bytes currently in flight.
    inflight_bytes: AtomicU64,
    high_water: AtomicU64,
    /// Interactive requests currently admitted — the preemption signal
    /// job workers poll at block boundaries.
    interactive_active: AtomicU64,
    read_gate: FairGate,
    write_gate: FairGate,
    job_gate: FairGate,
    admitted: Counter,
    throttled: Counter,
    shed: Counter,
    deadline_expired: Counter,
    preemptions: Counter,
}

impl Default for QosEnforcer {
    fn default() -> Self {
        Self::new()
    }
}

impl QosEnforcer {
    /// An enforcer with enforcement **off** and the default pool
    /// capacities: read = cores, write = 3·cores/4, job = cores/2 —
    /// reads get the whole machine, background work a bounded share.
    pub fn new() -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::with_capacities(cores, (cores * 3 / 4).max(1), (cores / 2).max(1))
    }

    pub fn with_capacities(read: usize, write: usize, job: usize) -> Self {
        let enabled = Arc::new(AtomicBool::new(false));
        QosEnforcer {
            read_gate: FairGate::new("read", read, enabled.clone()),
            write_gate: FairGate::new("write", write, enabled.clone()),
            job_gate: FairGate::new("job", job, enabled.clone()),
            enabled,
            tenants: RwLock::new(HashMap::new()),
            inflight_bytes: AtomicU64::new(0),
            high_water: AtomicU64::new(DEFAULT_HIGH_WATER_BYTES),
            interactive_active: AtomicU64::new(0),
            admitted: Counter::default(),
            throttled: Counter::default(),
            shed: Counter::default(),
            deadline_expired: Counter::default(),
            preemptions: Counter::default(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn high_water(&self) -> u64 {
        self.high_water.load(Ordering::Relaxed)
    }

    pub fn set_high_water(&self, bytes: u64) {
        self.high_water.store(bytes.max(1), Ordering::Relaxed);
    }

    /// Configure (or replace) `tenant`'s quota. Buckets restart full.
    pub fn set_quota(&self, tenant: &str, quota: Quota) {
        self.tenants
            .write()
            .unwrap()
            .insert(tenant.to_string(), Arc::new(TenantQos::new(quota)));
    }

    /// The configured quota for `tenant`, if any.
    pub fn quota(&self, tenant: &str) -> Option<Quota> {
        self.tenants.read().unwrap().get(tenant).map(|t| t.quota)
    }

    /// Fair-share weight for `tenant` (1 when unconfigured).
    pub fn weight(&self, tenant: &str) -> u64 {
        self.quota(tenant).map(|q| q.weight.max(1)).unwrap_or(1)
    }

    /// Drop all QoS state for a retired tenant (project deletion).
    pub fn retire_tenant(&self, tenant: &str) {
        self.tenants.write().unwrap().remove(tenant);
        self.read_gate.retire_tenant(tenant);
        self.write_gate.retire_tenant(tenant);
        self.job_gate.retire_tenant(tenant);
    }

    /// Admit one request of `bytes_in` payload attributed to `tenant`
    /// in route-class `class`. On success the returned guard holds the
    /// in-flight accounting until the response is written. Denials
    /// consume no tokens.
    pub fn admit(
        self: &Arc<Self>,
        tenant: Option<&str>,
        class: RouteClass,
        bytes_in: u64,
    ) -> std::result::Result<AdmitGuard, Denial> {
        if !self.enabled() {
            return Ok(AdmitGuard { enf: None, bytes: 0, interactive: false });
        }
        // Global overload guard: shed lowest-priority work first. Over
        // the high-water mark bulk is shed; over twice it, status work
        // too. Interactive is never shed — it is what the mark protects.
        let inflight = self.inflight_bytes.load(Ordering::Relaxed);
        let hw = self.high_water();
        let shed = match class {
            RouteClass::Bulk => inflight >= hw,
            RouteClass::Status => inflight >= hw.saturating_mul(2),
            RouteClass::Interactive => false,
        };
        if shed {
            self.shed.inc();
            return Err(Denial::Shed { class, retry_after: Duration::from_secs(1) });
        }
        // Per-tenant token buckets (tenants without a configured quota
        // are unlimited — admission cost stays one map lookup).
        if let Some(token) = tenant {
            let t = self.tenants.read().unwrap().get(token).cloned();
            if let Some(t) = t {
                if let Err(wait) = t.req.try_take(1.0) {
                    t.throttled.inc();
                    self.throttled.inc();
                    return Err(Denial::Throttled {
                        tenant: token.to_string(),
                        retry_after: wait,
                    });
                }
                if bytes_in > 0 {
                    if let Err(wait) = t.bytes.try_take(bytes_in as f64) {
                        t.req.give(1.0); // undo the sibling take
                        t.throttled.inc();
                        self.throttled.inc();
                        return Err(Denial::Throttled {
                            tenant: token.to_string(),
                            retry_after: wait,
                        });
                    }
                }
            }
        }
        let charged = bytes_in.max(1);
        self.inflight_bytes.fetch_add(charged, Ordering::Relaxed);
        let interactive = class == RouteClass::Interactive;
        if interactive {
            self.interactive_active.fetch_add(1, Ordering::Relaxed);
        }
        self.admitted.inc();
        Ok(AdmitGuard { enf: Some(self.clone()), bytes: charged, interactive })
    }

    /// Acquire a slot in `pool`'s fair gate for one batch of work,
    /// attributed from the ambient [`ctx`]. Engines call this at batch
    /// boundaries; it is a no-op while enforcement is off.
    pub fn enter(&self, pool: Pool) -> GateGuard<'_> {
        let gate = self.gate(pool);
        if !self.enabled() {
            // Fast path: skip the ctx lookup entirely.
            return gate.acquire_disabled();
        }
        let (class, tenant) = match ctx::current() {
            Some(c) => (c.class, c.tenant),
            None => (RouteClass::Interactive, None),
        };
        let weight = tenant.as_deref().map(|t| self.weight(t)).unwrap_or(1);
        gate.acquire(class, tenant, weight)
    }

    pub fn gate(&self, pool: Pool) -> &FairGate {
        match pool {
            Pool::Read => &self.read_gate,
            Pool::Write => &self.write_gate,
            Pool::Job => &self.job_gate,
        }
    }

    /// Block-boundary preemption point for job workers: while
    /// interactive requests are in flight, wait (bounded) before
    /// scheduling the next block. Returns whether the worker yielded.
    pub fn yield_to_interactive(&self) -> bool {
        if !self.enabled() || self.interactive_active.load(Ordering::Relaxed) == 0 {
            return false;
        }
        self.preemptions.inc();
        let give_up = Instant::now() + Duration::from_millis(250);
        while self.interactive_active.load(Ordering::Relaxed) > 0 && Instant::now() < give_up {
            std::thread::sleep(Duration::from_millis(2));
        }
        true
    }

    /// Count one request that died at its deadline (504).
    pub fn note_deadline_expired(&self) {
        self.deadline_expired.inc();
    }

    pub fn inflight_bytes(&self) -> u64 {
        self.inflight_bytes.load(Ordering::Relaxed)
    }

    pub fn interactive_active(&self) -> u64 {
        self.interactive_active.load(Ordering::Relaxed)
    }

    pub fn preemptions(&self) -> u64 {
        self.preemptions.get()
    }

    pub fn throttled_total(&self) -> u64 {
        self.throttled.get()
    }

    pub fn shed_total(&self) -> u64 {
        self.shed.get()
    }

    /// The `GET /qos/status/` body.
    pub fn status_text(&self) -> String {
        let mut out = String::from("qos:\n");
        out.push_str(&format!(
            "  enforcement: {}\n",
            if self.enabled() { "on" } else { "off" }
        ));
        out.push_str(&format!(
            "  inflight_bytes: {} high_water: {}\n",
            self.inflight_bytes(),
            self.high_water()
        ));
        out.push_str(&format!(
            "  admitted: {} throttled: {} shed: {} deadline_expired: {} preemptions: {}\n",
            self.admitted.get(),
            self.throttled.get(),
            self.shed.get(),
            self.deadline_expired.get(),
            self.preemptions.get()
        ));
        out.push_str(&format!("  interactive_active: {}\n", self.interactive_active()));
        for pool in [Pool::Read, Pool::Write, Pool::Job] {
            let g = self.gate(pool);
            out.push_str(&format!(
                "  gate {}: capacity={} waiting={} granted_interactive={} \
                 granted_status={} granted_bulk={}\n",
                g.name(),
                g.capacity(),
                g.waiting(),
                g.granted(RouteClass::Interactive),
                g.granted(RouteClass::Status),
                g.granted(RouteClass::Bulk),
            ));
        }
        let mut tenants: Vec<(String, Arc<TenantQos>)> = self
            .tenants
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        tenants.sort_by(|a, b| a.0.cmp(&b.0));
        for (token, t) in tenants {
            out.push_str(&format!(
                "  tenant {token}: req_per_s={} bytes_per_s={} weight={} \
                 req_tokens={:.1} byte_tokens={:.0} throttled={}\n",
                t.quota.req_per_s,
                t.quota.bytes_per_s,
                t.quota.weight,
                t.req.level(),
                t.bytes.level(),
                t.throttled.get(),
            ));
        }
        out
    }

    /// Emit the `ocpd_qos_*` families (the cluster registers this under
    /// the `"qos"` collector key).
    pub fn collect(&self, out: &mut Vec<Sample>) {
        out.push(Sample::gauge(
            "ocpd_qos_enforcement_enabled",
            "1 while QoS enforcement is active.",
            self.enabled() as u64,
        ));
        out.push(Sample::gauge(
            "ocpd_qos_inflight_bytes",
            "Admitted request-payload bytes currently in flight.",
            self.inflight_bytes(),
        ));
        out.push(Sample::gauge(
            "ocpd_qos_interactive_active",
            "Interactive requests currently admitted (the preemption signal).",
            self.interactive_active(),
        ));
        out.push(Sample::counter(
            "ocpd_qos_admitted_total",
            "Requests admitted past the QoS gate.",
            self.admitted.get(),
        ));
        out.push(Sample::counter(
            "ocpd_qos_shed_total",
            "Requests shed (503) by the global overload guard.",
            self.shed.get(),
        ));
        out.push(Sample::counter(
            "ocpd_qos_deadline_expired_total",
            "Requests that died at their deadline (504).",
            self.deadline_expired.get(),
        ));
        out.push(Sample::counter(
            "ocpd_qos_preemptions_total",
            "Job-block yields to in-flight interactive work.",
            self.preemptions.get(),
        ));
        for (token, t) in self.tenants.read().unwrap().iter() {
            out.push(
                Sample::counter(
                    "ocpd_qos_throttled_total",
                    "Requests throttled (429) per tenant.",
                    t.throttled.get(),
                )
                .label("project", token.clone()),
            );
            out.push(
                Sample::gauge(
                    "ocpd_qos_tokens",
                    "Token-bucket level per tenant and bucket kind.",
                    t.req.level().clamp(0.0, 1e18) as u64,
                )
                .label("project", token.clone())
                .label("kind", "req"),
            );
            out.push(
                Sample::gauge(
                    "ocpd_qos_tokens",
                    "Token-bucket level per tenant and bucket kind.",
                    t.bytes.level().clamp(0.0, 1e18) as u64,
                )
                .label("project", token.clone())
                .label("kind", "bytes"),
            );
        }
        for pool in [Pool::Read, Pool::Write, Pool::Job] {
            let g = self.gate(pool);
            for class in [RouteClass::Interactive, RouteClass::Status, RouteClass::Bulk] {
                out.push(
                    Sample::histogram(
                        "ocpd_qos_queue_wait_us",
                        "Fair-gate queue wait per pool and class, microseconds.",
                        g.wait_hist(class).snapshot(),
                    )
                    .label("pool", pool.name())
                    .label("class", class.name()),
                );
            }
        }
    }
}

/// Releases a request's in-flight accounting on drop (response
/// written or connection torn down).
pub struct AdmitGuard {
    enf: Option<Arc<QosEnforcer>>,
    bytes: u64,
    interactive: bool,
}

impl Drop for AdmitGuard {
    fn drop(&mut self) {
        if let Some(enf) = &self.enf {
            enf.inflight_bytes.fetch_sub(self.bytes, Ordering::Relaxed);
            if self.interactive {
                enf.interactive_active.fetch_sub(1, Ordering::Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enforcer_on() -> Arc<QosEnforcer> {
        let e = Arc::new(QosEnforcer::new());
        e.set_enabled(true);
        e
    }

    #[test]
    fn disabled_enforcer_admits_everything() {
        let e = Arc::new(QosEnforcer::new());
        e.set_quota("t", Quota { req_per_s: 0.001, bytes_per_s: 1.0, weight: 1 });
        for _ in 0..100 {
            assert!(e.admit(Some("t"), RouteClass::Bulk, 1 << 20).is_ok());
        }
        assert_eq!(e.inflight_bytes(), 0, "disabled admits carry no accounting");
    }

    #[test]
    fn req_bucket_throttles_and_reports_refill_wait() {
        let e = enforcer_on();
        e.set_quota("t", Quota { req_per_s: 2.0, bytes_per_s: f64::INFINITY, weight: 1 });
        // Burst = 2 requests; the third inside the same instant denies.
        let _a = e.admit(Some("t"), RouteClass::Interactive, 0).unwrap();
        let _b = e.admit(Some("t"), RouteClass::Interactive, 0).unwrap();
        match e.admit(Some("t"), RouteClass::Interactive, 0) {
            Err(d @ Denial::Throttled { .. }) => {
                assert_eq!(d.http_status(), 429);
                // One token at 2/s regenerates in ≤ 0.5s → Retry-After
                // rounds up to exactly 1.
                assert_eq!(d.retry_after_secs(), 1);
            }
            other => panic!("expected throttle, got {other:?}"),
        }
        assert_eq!(e.throttled_total(), 1);
        // Unquota'd tenants are untouched.
        assert!(e.admit(Some("other"), RouteClass::Interactive, 0).is_ok());
    }

    #[test]
    fn byte_bucket_throttles_and_refunds_the_request_token() {
        let e = enforcer_on();
        e.set_quota("t", Quota { req_per_s: 1000.0, bytes_per_s: 100_000.0, weight: 1 });
        // 100 KB/s, 100 KB burst: a 60 KB put fits, the next one trips.
        assert!(e.admit(Some("t"), RouteClass::Bulk, 60_000).is_ok());
        assert!(matches!(
            e.admit(Some("t"), RouteClass::Bulk, 60_000),
            Err(Denial::Throttled { .. })
        ));
        // The refunded request token is still spendable on a small op.
        assert!(e.admit(Some("t"), RouteClass::Status, 10).is_ok());
    }

    #[test]
    fn overload_guard_sheds_bulk_then_status_never_interactive() {
        let e = enforcer_on();
        e.set_high_water(1000);
        let _big = e.admit(None, RouteClass::Bulk, 1000).unwrap();
        // At the mark: bulk sheds, status and interactive pass.
        assert!(matches!(e.admit(None, RouteClass::Bulk, 10), Err(Denial::Shed { .. })));
        assert!(e.admit(None, RouteClass::Status, 10).is_ok());
        let _big2 = e.admit(None, RouteClass::Interactive, 1200).unwrap();
        // Over 2x: status sheds too; interactive still passes.
        assert!(matches!(e.admit(None, RouteClass::Status, 10), Err(Denial::Shed { .. })));
        let ia = e.admit(None, RouteClass::Interactive, 10);
        assert!(ia.is_ok());
        assert_eq!(e.shed_total(), 2);
        assert_eq!(e.interactive_active(), 2);
        drop(ia);
        assert_eq!(e.interactive_active(), 1);
    }

    #[test]
    fn admit_guard_releases_inflight_accounting() {
        let e = enforcer_on();
        let g = e.admit(Some("t"), RouteClass::Interactive, 500).unwrap();
        assert_eq!(e.inflight_bytes(), 500);
        assert_eq!(e.interactive_active(), 1);
        drop(g);
        assert_eq!(e.inflight_bytes(), 0);
        assert_eq!(e.interactive_active(), 0);
    }

    #[test]
    fn yield_to_interactive_waits_only_while_interactive_in_flight() {
        let e = enforcer_on();
        assert!(!e.yield_to_interactive(), "nothing to yield to");
        let g = e.admit(None, RouteClass::Interactive, 0).unwrap();
        let t0 = Instant::now();
        let e2 = e.clone();
        let h = std::thread::spawn(move || e2.yield_to_interactive());
        std::thread::sleep(Duration::from_millis(10));
        drop(g);
        assert!(h.join().unwrap(), "should report a yield");
        assert!(t0.elapsed() < Duration::from_millis(250), "released early on guard drop");
        assert_eq!(e.preemptions(), 1);
    }

    #[test]
    fn retire_tenant_drops_quota_state() {
        let e = enforcer_on();
        e.set_quota("gone", Quota { req_per_s: 1.0, bytes_per_s: 1.0, weight: 5 });
        assert_eq!(e.weight("gone"), 5);
        e.retire_tenant("gone");
        assert!(e.quota("gone").is_none());
        assert_eq!(e.weight("gone"), 1);
    }

    #[test]
    fn status_text_lists_tenants_and_gates() {
        let e = enforcer_on();
        e.set_quota("alpha", Quota { req_per_s: 10.0, bytes_per_s: 1e6, weight: 2 });
        let txt = e.status_text();
        assert!(txt.contains("enforcement: on"), "{txt}");
        assert!(txt.contains("gate read:"), "{txt}");
        assert!(txt.contains("tenant alpha:"), "{txt}");
        assert!(txt.contains("weight=2"), "{txt}");
    }

    #[test]
    fn collector_emits_qos_families() {
        let e = enforcer_on();
        e.set_quota("t", Quota { req_per_s: 5.0, bytes_per_s: 1e6, weight: 1 });
        let _g = e.enter(Pool::Read);
        let mut out = Vec::new();
        e.collect(&mut out);
        let names: Vec<&str> = out.iter().map(|s| s.name).collect();
        for family in [
            "ocpd_qos_enforcement_enabled",
            "ocpd_qos_inflight_bytes",
            "ocpd_qos_admitted_total",
            "ocpd_qos_throttled_total",
            "ocpd_qos_shed_total",
            "ocpd_qos_preemptions_total",
            "ocpd_qos_tokens",
            "ocpd_qos_queue_wait_us",
        ] {
            assert!(names.contains(&family), "missing {family}");
        }
    }
}
