//! Per-request QoS context: route class, tenant, and deadline, carried
//! on a thread-local stack exactly like [`crate::obs::trace`]'s active
//! trace — deep layers (a cutout batch worker, a WAL flusher invoked
//! from a handler) consult it without plumbing a parameter through
//! every signature, and [`scoped_map`] propagates it onto fork-join
//! workers via [`current`]/[`install`].
//!
//! The context is installed at the admission point (`OcpService::
//! handle`) for HTTP requests, and by the job engine's block workers
//! (as [`RouteClass::Bulk`], so job-driven reads queue behind
//! interactive ones inside the fair gates). Code running with *no*
//! context — direct library use, unit tests — is treated as
//! interactive and undeadlined: un-attributed work is never throttled
//! or expired.
//!
//! [`scoped_map`]: crate::util::pool::scoped_map

use std::cell::RefCell;
use std::sync::Arc;
use std::time::Instant;

use crate::obs::slo::RouteClass;
use crate::{Error, Result};

/// The ambient QoS identity of the work on this thread.
#[derive(Clone, Debug)]
pub struct ReqCtx {
    /// Route class from [`crate::obs::slo::class_of_route`].
    pub class: RouteClass,
    /// Project token the request was attributed to, if any.
    pub tenant: Option<Arc<str>>,
    /// Absolute expiry (from `X-OCPD-Deadline-Ms`), if the caller set one.
    pub deadline: Option<Instant>,
}

impl ReqCtx {
    /// A bulk-class context for background work attributed to `tenant`.
    pub fn bulk(tenant: Option<Arc<str>>) -> Self {
        ReqCtx { class: RouteClass::Bulk, tenant, deadline: None }
    }
}

thread_local! {
    static CURRENT: RefCell<Vec<ReqCtx>> = const { RefCell::new(Vec::new()) };
}

/// The innermost installed context, if any (cloned; cheap — the tenant
/// is an `Arc<str>`).
pub fn current() -> Option<ReqCtx> {
    CURRENT.with(|c| c.borrow().last().cloned())
}

/// Route class of the current work; [`RouteClass::Interactive`] when no
/// context is installed (un-attributed work is never deprioritized).
pub fn class() -> RouteClass {
    CURRENT.with(|c| c.borrow().last().map(|x| x.class).unwrap_or(RouteClass::Interactive))
}

/// Tenant of the current work, if attributed.
pub fn tenant() -> Option<Arc<str>> {
    CURRENT.with(|c| c.borrow().last().and_then(|x| x.tenant.clone()))
}

/// Deadline of the current work, if the caller set one.
pub fn deadline() -> Option<Instant> {
    CURRENT.with(|c| c.borrow().last().and_then(|x| x.deadline))
}

/// Fail with [`Error::DeadlineExceeded`] if the current context's
/// deadline has passed. Engines call this at batch boundaries so an
/// expired request stops burning workers instead of finishing work
/// nobody will wait for.
pub fn check_deadline() -> Result<()> {
    if let Some(d) = deadline() {
        if Instant::now() >= d {
            return Err(Error::DeadlineExceeded(
                "request deadline expired before completion".into(),
            ));
        }
    }
    Ok(())
}

/// Install `ctx` on this thread for the guard's lifetime. `None` is a
/// no-op guard, so call sites forward `current()` unconditionally.
pub fn install(ctx: Option<ReqCtx>) -> InstallGuard {
    match ctx {
        Some(c) => {
            CURRENT.with(|s| s.borrow_mut().push(c));
            InstallGuard { installed: true }
        }
        None => InstallGuard { installed: false },
    }
}

/// Pops the installed context on drop.
pub struct InstallGuard {
    installed: bool,
}

impl Drop for InstallGuard {
    fn drop(&mut self) {
        if self.installed {
            CURRENT.with(|s| {
                s.borrow_mut().pop();
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn no_context_defaults_to_interactive_and_no_deadline() {
        assert!(current().is_none());
        assert_eq!(class(), RouteClass::Interactive);
        assert!(tenant().is_none());
        assert!(check_deadline().is_ok());
    }

    #[test]
    fn install_stacks_and_pops() {
        let outer = ReqCtx { class: RouteClass::Bulk, tenant: Some("t1".into()), deadline: None };
        let g1 = install(Some(outer));
        assert_eq!(class(), RouteClass::Bulk);
        assert_eq!(tenant().as_deref(), Some("t1"));
        {
            let inner =
                ReqCtx { class: RouteClass::Status, tenant: Some("t2".into()), deadline: None };
            let _g2 = install(Some(inner));
            assert_eq!(class(), RouteClass::Status);
            assert_eq!(tenant().as_deref(), Some("t2"));
        }
        assert_eq!(class(), RouteClass::Bulk);
        drop(g1);
        assert!(current().is_none());
    }

    #[test]
    fn none_install_is_a_no_op() {
        let _g = install(None);
        assert!(current().is_none());
    }

    #[test]
    fn expired_deadline_fails_check() {
        let past = Instant::now() - Duration::from_millis(1);
        let _g = install(Some(ReqCtx {
            class: RouteClass::Interactive,
            tenant: None,
            deadline: Some(past),
        }));
        match check_deadline() {
            Err(Error::DeadlineExceeded(_)) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        let future = Instant::now() + Duration::from_secs(60);
        let _g2 = install(Some(ReqCtx {
            class: RouteClass::Interactive,
            tenant: None,
            deadline: Some(future),
        }));
        assert!(check_deadline().is_ok());
    }

    #[test]
    fn scoped_map_carries_the_context() {
        let _g = install(Some(ReqCtx {
            class: RouteClass::Bulk,
            tenant: Some("carried".into()),
            deadline: None,
        }));
        let seen = crate::util::pool::scoped_map(4, 4, |_| (class(), tenant()));
        for (c, t) in seen {
            assert_eq!(c, RouteClass::Bulk);
            assert_eq!(t.as_deref(), Some("carried"));
        }
    }
}
