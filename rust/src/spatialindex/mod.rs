//! The sparse per-object spatial index (§4.2 "Spatial Queries and
//! Indexing Objects", Figure 9).
//!
//! For each annotation identifier the index stores the list of Morton
//! locations of the cuboids containing that object's voxels. The design
//! choices mirror the paper:
//!
//! * **append-mostly**: writes collect the cuboids newly touched by each
//!   id and append them to the blob in one batch transaction;
//! * **batch retrieval**: reading an object fetches its cuboid list,
//!   sorts it, and retrieves all cuboids in a single Morton-ordered
//!   sequential pass;
//! * the blob is delta-varint coded (the paper stored a Python array and
//!   notes the index "is not particularly compact" — ours is).
//!
//! The per-table mutex emulates MySQL's transactional serialization on
//! index updates; under many parallel writers this is precisely the
//! contention that collapses write throughput in Figure 12.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::core::Project;
use crate::storage::Engine;
use crate::util::codec::{Dec, Enc};
use crate::Result;

/// Per-object cuboid-list index for one project.
pub struct SpatialIndex {
    engine: Engine,
    project: Arc<Project>,
    /// Commit lock: the write phase of an index transaction is atomic.
    txn: Mutex<()>,
    /// Commit counter for optimistic validation: an appender reads its
    /// entries lock-free, then validates nothing committed in between;
    /// on conflict it retries (re-reading — wasted I/O). This is the
    /// MySQL behaviour behind Figure 12's write collapse: "Parallel
    /// writes to the spatial index result in transaction retries and
    /// timeouts ... due to contention."
    version: AtomicU64,
    /// Observability: conflicted (retried) transactions.
    pub retries: crate::metrics::Counter,
}

/// Optimistic attempts before falling back to a pessimistic hold.
const MAX_OPTIMISTIC: usize = 3;

impl SpatialIndex {
    pub fn new(project: Arc<Project>, engine: Engine) -> Self {
        SpatialIndex {
            engine,
            project,
            txn: Mutex::new(()),
            version: AtomicU64::new(0),
            retries: crate::metrics::Counter::default(),
        }
    }

    fn decode_list(buf: &[u8]) -> Result<Vec<u64>> {
        Dec::new(buf).sorted_u64s()
    }

    fn encode_list(codes: &[u64]) -> Vec<u8> {
        let mut e = Enc::with_capacity(codes.len() + 8);
        e.sorted_u64s(codes);
        e.finish()
    }

    /// The sorted cuboid (Morton) list for `id` at `res` — empty if the
    /// object has no voxels there.
    pub fn cuboids_of(&self, res: u32, id: u32) -> Result<Vec<u64>> {
        match self.engine.get(&self.project.index_table(res), id as u64)? {
            Some(v) => Self::decode_list(&v),
            None => Ok(Vec::new()),
        }
    }

    /// Append newly-touched cuboid locations for many objects in one
    /// transaction: the paper's steps (4) read index entries, (5) union
    /// new and old lists, (6) write back (§5).
    ///
    /// Concurrency follows MySQL's optimistic pattern: the read+union
    /// phase runs lock-free; the commit validates that no other
    /// transaction committed in between, otherwise the whole read phase
    /// is retried (wasted I/O — the source of Figure 12's write-
    /// throughput collapse under many parallel annotators). After
    /// [`MAX_OPTIMISTIC`] conflicts the appender commits pessimistically.
    pub fn append_batch(&self, res: u32, updates: &HashMap<u32, Vec<u64>>) -> Result<()> {
        if updates.is_empty() {
            return Ok(());
        }
        let table = self.project.index_table(res);
        // Deterministic order for reproducible I/O patterns.
        let mut ids: Vec<u32> = updates.keys().copied().collect();
        ids.sort_unstable();

        for attempt in 0.. {
            let pessimistic = attempt >= MAX_OPTIMISTIC;
            let held = if pessimistic { Some(self.txn.lock().unwrap()) } else { None };
            let v0 = self.version.load(Ordering::Acquire);

            // (4) + (5): read entries and union in the new locations.
            let mut batch = Vec::with_capacity(ids.len());
            for &id in &ids {
                let new_codes = &updates[&id];
                let mut list = match self.engine.get(&table, id as u64)? {
                    Some(v) => Self::decode_list(&v)?,
                    None => Vec::new(),
                };
                let before = list.len();
                list.extend(new_codes.iter().copied());
                list.sort_unstable();
                list.dedup();
                if list.len() != before {
                    batch.push((id as u64, Self::encode_list(&list)));
                }
            }

            // (6): commit under the lock, validating the read snapshot.
            let _commit = match held {
                Some(g) => g,
                None => self.txn.lock().unwrap(),
            };
            if !pessimistic && self.version.load(Ordering::Acquire) != v0 {
                // Conflict: another transaction committed entries we may
                // have read stale. Back off (MySQL's lock-wait behaviour
                // — the "transaction retries and timeouts" of §5) and
                // retry from the read phase.
                self.retries.inc();
                drop(_commit);
                std::thread::sleep(std::time::Duration::from_millis(
                    25 * (attempt as u64 + 1),
                ));
                continue;
            }
            if !batch.is_empty() {
                self.engine.put_batch(&table, &batch)?;
            }
            self.version.fetch_add(1, Ordering::AcqRel);
            return Ok(());
        }
        unreachable!()
    }

    /// Remove cuboid locations for an object (annotation deletion — rare;
    /// the workload is append-mostly).
    pub fn remove(&self, res: u32, id: u32, codes: &[u64]) -> Result<()> {
        let _txn = self.txn.lock().unwrap();
        let table = self.project.index_table(res);
        let mut list = match self.engine.get(&table, id as u64)? {
            Some(v) => Self::decode_list(&v)?,
            None => return Ok(()),
        };
        list.retain(|c| !codes.contains(c));
        if list.is_empty() {
            self.engine.delete(&table, id as u64)
        } else {
            self.engine.put(&table, id as u64, &Self::encode_list(&list))
        }
    }

    /// Drop an object's index entry entirely.
    pub fn delete(&self, res: u32, id: u32) -> Result<()> {
        let _txn = self.txn.lock().unwrap();
        self.engine.delete(&self.project.index_table(res), id as u64)
    }

    /// All indexed object ids at `res`.
    pub fn ids(&self, res: u32) -> Result<Vec<u32>> {
        Ok(self
            .engine
            .keys(&self.project.index_table(res))?
            .into_iter()
            .map(|k| k as u32)
            .collect())
    }

    /// Stored index size for an object, bytes (compactness ablation).
    pub fn entry_bytes(&self, res: u32, id: u32) -> Result<usize> {
        Ok(self
            .engine
            .get(&self.project.index_table(res), id as u64)?
            .map(|v| v.len())
            .unwrap_or(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::morton;
    use crate::storage::MemStore;
    use crate::util::prop::property;

    fn index() -> SpatialIndex {
        SpatialIndex::new(
            Arc::new(Project::annotation("ann", "ds")),
            Arc::new(MemStore::new()),
        )
    }

    #[test]
    fn append_union_sorted_dedup() {
        let idx = index();
        let mut u = HashMap::new();
        u.insert(7u32, vec![30u64, 10, 20]);
        idx.append_batch(0, &u).unwrap();
        let mut u2 = HashMap::new();
        u2.insert(7u32, vec![20u64, 5, 40]);
        idx.append_batch(0, &u2).unwrap();
        assert_eq!(idx.cuboids_of(0, 7).unwrap(), vec![5, 10, 20, 30, 40]);
    }

    #[test]
    fn missing_object_empty() {
        let idx = index();
        assert!(idx.cuboids_of(0, 999).unwrap().is_empty());
        assert_eq!(idx.entry_bytes(0, 999).unwrap(), 0);
    }

    #[test]
    fn resolutions_are_separate() {
        let idx = index();
        let mut u = HashMap::new();
        u.insert(1u32, vec![1u64]);
        idx.append_batch(0, &u).unwrap();
        assert!(idx.cuboids_of(1, 1).unwrap().is_empty());
        assert_eq!(idx.cuboids_of(0, 1).unwrap(), vec![1]);
    }

    #[test]
    fn remove_and_delete() {
        let idx = index();
        let mut u = HashMap::new();
        u.insert(3u32, vec![1u64, 2, 3]);
        idx.append_batch(0, &u).unwrap();
        idx.remove(0, 3, &[2]).unwrap();
        assert_eq!(idx.cuboids_of(0, 3).unwrap(), vec![1, 3]);
        idx.remove(0, 3, &[1, 3]).unwrap();
        assert!(idx.cuboids_of(0, 3).unwrap().is_empty());
        // Delete is idempotent.
        idx.delete(0, 3).unwrap();
    }

    #[test]
    fn ids_lists_all() {
        let idx = index();
        let mut u = HashMap::new();
        u.insert(10u32, vec![1u64]);
        u.insert(20u32, vec![2u64]);
        idx.append_batch(0, &u).unwrap();
        let mut ids = idx.ids(0).unwrap();
        ids.sort_unstable();
        assert_eq!(ids, vec![10, 20]);
    }

    #[test]
    fn blob_is_compact_for_clustered_objects() {
        // Neural objects are long and skinny: their cuboids cluster along
        // the curve, so delta coding stores ~1-2 bytes per cuboid.
        let idx = index();
        let codes: Vec<u64> =
            (0..1000u64).map(|i| morton::encode3(i % 64, i / 64, 3)).collect();
        let mut sorted = codes.clone();
        sorted.sort_unstable();
        let mut u = HashMap::new();
        u.insert(1u32, sorted);
        idx.append_batch(0, &u).unwrap();
        let bytes = idx.entry_bytes(0, 1).unwrap();
        assert!(bytes < 4_000, "index blob {bytes}B for 1000 cuboids");
    }

    #[test]
    fn concurrent_appends_serialize_correctly() {
        let idx = Arc::new(index());
        std::thread::scope(|s| {
            for w in 0..8u64 {
                let idx = Arc::clone(&idx);
                s.spawn(move || {
                    for i in 0..50u64 {
                        let mut u = HashMap::new();
                        u.insert(1u32, vec![w * 1000 + i]);
                        idx.append_batch(0, &u).unwrap();
                    }
                });
            }
        });
        assert_eq!(idx.cuboids_of(0, 1).unwrap().len(), 400);
    }

    #[test]
    fn interleaved_multi_id_appends_roundtrip_prop() {
        // The delta-varint blob must round-trip under interleaved
        // appends across several ids: each id's retrieved list is the
        // sorted, deduplicated union of everything appended for it, in
        // strictly ascending (Morton) order — regardless of how appends
        // for different ids interleave.
        property("index_interleaved_multi_id", 60, |g| {
            let idx = index();
            let n_ids = 1 + g.usize_below(4) as u32;
            let rounds = 1 + g.usize_below(6);
            let mut expect: HashMap<u32, Vec<u64>> = HashMap::new();
            for _ in 0..rounds {
                let mut updates: HashMap<u32, Vec<u64>> = HashMap::new();
                for id in 1..=n_ids {
                    if g.chance(0.7) {
                        let n = 1 + g.usize_below(20);
                        let codes = g.vec_u64(n, 4096);
                        expect.entry(id).or_default().extend(&codes);
                        updates.insert(id, codes);
                    }
                }
                idx.append_batch(0, &updates).unwrap();
            }
            for (id, mut codes) in expect {
                codes.sort_unstable();
                codes.dedup();
                let got = idx.cuboids_of(0, id).unwrap();
                assert_eq!(got, codes, "id {id}");
                assert!(
                    got.windows(2).all(|w| w[0] < w[1]),
                    "id {id}: retrieval must stay strictly Morton-sorted"
                );
                // The stored blob is the compact delta coding, not the
                // raw 8-byte-per-code array.
                if got.len() > 16 {
                    assert!(idx.entry_bytes(0, id).unwrap() < got.len() * 8);
                }
            }
        });
    }

    #[test]
    fn voxel_list_retrieval_stays_morton_sorted() {
        // End to end through AnnotationDb: the per-object cuboid list
        // feeding voxel_list is Morton-sorted, and the voxel list comes
        // back sorted — the single sequential pass of Figure 9.
        use crate::annotation::AnnotationDb;
        use crate::array::DenseVolume;
        use crate::chunkstore::CuboidStore;
        use crate::core::{Box3, DatasetBuilder, WriteDiscipline};
        let ds = Arc::new(DatasetBuilder::new("t", [256, 256, 32]).levels(1).build());
        let pr = Arc::new(Project::annotation("ann", "t"));
        let engine: crate::storage::Engine = Arc::new(MemStore::new());
        let store = Arc::new(CuboidStore::new(ds, pr, Arc::clone(&engine)));
        let db = AnnotationDb::new(store, engine).unwrap();
        // Two writes of one id in distinct cuboids, out of curve order.
        for lo in [[200u64, 200, 20], [0, 0, 0]] {
            let bx = Box3::at(lo, [16, 16, 4]);
            let mut v = DenseVolume::<u32>::zeros(bx.extent());
            v.fill_box(Box3::new([0, 0, 0], bx.extent()), 7);
            db.write_volume(0, bx, &v, WriteDiscipline::Overwrite).unwrap();
        }
        let codes = db.index.cuboids_of(0, 7).unwrap();
        assert!(codes.len() >= 2);
        assert!(codes.windows(2).all(|w| w[0] < w[1]), "index Morton-sorted");
        let voxels = db.voxel_list(0, 7).unwrap();
        assert_eq!(voxels.len(), 2 * 16 * 16 * 4);
        assert!(voxels.windows(2).all(|w| w[0] < w[1]), "voxel list sorted");
    }

    #[test]
    fn append_batch_prop_union_semantics() {
        property("index_union", 100, |g| {
            let idx = index();
            let na = g.usize_below(40);
            let a = {
                let mut v = g.vec_u64(na, 500);
                v.sort_unstable();
                v.dedup();
                v
            };
            let nb = g.usize_below(40);
            let b = {
                let mut v = g.vec_u64(nb, 500);
                v.sort_unstable();
                v.dedup();
                v
            };
            let mut u = HashMap::new();
            u.insert(1u32, a.clone());
            idx.append_batch(0, &u).unwrap();
            let mut u2 = HashMap::new();
            u2.insert(1u32, b.clone());
            idx.append_batch(0, &u2).unwrap();
            let mut expect: Vec<u64> = a.into_iter().chain(b).collect();
            expect.sort_unstable();
            expect.dedup();
            assert_eq!(idx.cuboids_of(0, 1).unwrap(), expect);
        });
    }
}
