//! # ocpd — The Open Connectome Project Data Cluster, reproduced
//!
//! A from-scratch reimplementation of the OCP Data Cluster (Burns et al.,
//! SSDBM '13): a spatial database cluster for the storage, cutout, and
//! annotation of high-throughput volumetric neuroimaging data, designed to
//! feed parallel computer-vision workloads that build *connectomes*.
//!
//! The system is a three-layer stack:
//!
//! * **Layer 3 (this crate)** — the Rust coordinator: cuboid storage under a
//!   Morton-order space-filling curve ([`morton`]), the parallel cutout
//!   read engine ([`cutout`]) with its sharded LRU cuboid cache
//!   ([`chunkstore::CuboidCache`]), RAMON annotation databases
//!   ([`annotation`]) with a sparse per-object spatial index
//!   ([`spatialindex`]), multi-resolution hierarchies ([`resolution`]),
//!   Morton-partition sharding across heterogeneous node roles
//!   ([`shard`], [`cluster`]), an SSD write-absorber — a segmented
//!   write-ahead log with group commit, read-through overlay and
//!   background flush to database nodes ([`wal`]) — a checkpointed batch
//!   compute engine for propagation, synapse detection, and bulk ingest
//!   ([`jobs`]), and a RESTful HTTP front end ([`web`]) speaking the URL
//!   grammar of the paper's Table 1.
//! * **Layer 2 (JAX, build time)** — the vision compute graphs (synapse
//!   detector, gradient-domain color correction, hierarchy down-sampler),
//!   lowered once to HLO text under `artifacts/`.
//! * **Layer 1 (Pallas, build time)** — the per-voxel hot loops of those
//!   graphs, tiled to the cuboid geometry.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT CPU
//! client; [`vision`] drives the paper's parallel synapse-finding workflow
//! end to end. Python never runs on the request path.
//!
//! See `DESIGN.md` (repository root) for the layer inventory, the offline
//! vendor-set substitutions, and the WAL subsystem's design and REST
//! surface.

pub mod annotation;
pub mod array;
pub mod chunkstore;
pub mod client;
pub mod cluster;
pub mod core;
pub mod cutout;
pub mod ingest;
pub mod jobs;
pub mod loadgen;
pub mod metrics;
pub mod morton;
pub mod obs;
pub mod qos;
pub mod resolution;
pub mod runtime;
pub mod shard;
pub mod spatialindex;
pub mod storage;
pub mod tiles;
pub mod util;
pub mod vision;
pub mod wal;
pub mod web;

pub use crate::core::{Dataset, DatasetBuilder, Dtype, Project, ProjectKind};
pub use crate::cutout::CutoutService;

/// Crate-wide result type.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("I/O error: {0}")]
    Io(#[from] std::io::Error),
    #[error("storage error: {0}")]
    Storage(String),
    #[error("bad request: {0}")]
    BadRequest(String),
    #[error("not found: {0}")]
    NotFound(String),
    #[error("codec error: {0}")]
    Codec(String),
    #[error("xla error: {0}")]
    Xla(String),
    #[error("cluster error: {0}")]
    Cluster(String),
    /// A routed operation carried a stale shard-map epoch: the shard's
    /// leadership changed (failover) after the caller cached its view.
    /// Callers refresh the epoch and retry against the new leader.
    #[error("epoch fence: held {held}, current {current}")]
    Fenced { held: u64, current: u64 },
    /// The target storage node is down (crashed or unreachable), as
    /// opposed to a transient per-operation failure.
    #[error("node down: {0}")]
    NodeDown(String),
    /// The caller is over its QoS quota: retry after the bucket refills
    /// (`Retry-After` is derived from `retry_after_ms`).
    #[error("throttled: retry after {retry_after_ms}ms")]
    Throttled { retry_after_ms: u64 },
    /// The request's `X-OCPD-Deadline-Ms` budget ran out before the
    /// work finished; remaining work was abandoned.
    #[error("deadline exceeded: {0}")]
    DeadlineExceeded(String),
    #[error("{0}")]
    Other(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// HTTP status code this error maps to at the web layer.
    pub fn http_status(&self) -> u16 {
        match self {
            Error::BadRequest(_) => 400,
            Error::NotFound(_) => 404,
            Error::Fenced { .. } => 409,
            Error::Throttled { .. } => 429,
            Error::NodeDown(_) => 503,
            Error::DeadlineExceeded(_) => 504,
            _ => 500,
        }
    }
}
