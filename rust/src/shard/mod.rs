//! Morton-curve sharding: partitioning the space-filling curve across
//! nodes (§4.1 "Data Distribution", Figure 4).
//!
//! The paper shards its largest dataset (bock11) by partitioning the
//! Morton-order curve at the application level: "The application is aware
//! of the data distribution and redirects requests to the node that
//! stores the data." A [`ShardMap`] holds the split points; the router
//! groups cuboid keys by owning node so each node receives one batched,
//! Morton-ordered request. The parallel cutout engine
//! ([`crate::cutout`]) also uses the map to align its fan-out batches to
//! shard boundaries, so no batch straddles two nodes.
//!
//! ```
//! use ocpd::shard::ShardMap;
//!
//! // 16 keys over 4 nodes — Figure 4's even partition.
//! let map = ShardMap::even(16, vec![0, 1, 2, 3]).unwrap();
//! assert_eq!(map.node_for(5), 1);
//! // A run crossing a boundary splits into per-node sub-runs.
//! assert_eq!(map.route_run(2, 4), vec![(0, 2, 2), (1, 4, 2)]);
//! ```

use crate::{Error, Result};

/// Identifies a node within a cluster.
pub type NodeId = usize;

/// A partition of the Morton key space: `splits[i]` is the first key of
/// shard `i + 1`. `n` shards need `n - 1` ascending split points.
///
/// Maps are immutable values: [`ShardMap::split`], [`ShardMap::merge`],
/// and [`ShardMap::assign`] return a *new* map whose `version` is one
/// past the source's, so a topology swap can be fenced the same way a
/// leader promotion is (DESIGN.md §13).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardMap {
    splits: Vec<u64>,
    nodes: Vec<NodeId>,
    version: u64,
}

impl ShardMap {
    /// A single-node (unsharded) map.
    pub fn single(node: NodeId) -> Self {
        ShardMap { splits: Vec::new(), nodes: vec![node], version: 0 }
    }

    /// Build from explicit split points (ascending) and one node per
    /// resulting shard.
    pub fn new(splits: Vec<u64>, nodes: Vec<NodeId>) -> Result<Self> {
        if nodes.is_empty() {
            return Err(Error::Cluster("shard map needs >= 1 node".into()));
        }
        if nodes.len() != splits.len() + 1 {
            return Err(Error::Cluster(format!(
                "{} nodes need {} splits, got {}",
                nodes.len(),
                nodes.len() - 1,
                splits.len()
            )));
        }
        if splits.windows(2).any(|w| w[0] >= w[1]) {
            return Err(Error::Cluster("split points must be strictly ascending".into()));
        }
        Ok(ShardMap { splits, nodes, version: 0 })
    }

    /// Partition a Morton key space of `total_keys` evenly across `nodes`
    /// — the Figure 4 construction (equal curve segments per node).
    pub fn even(total_keys: u64, nodes: Vec<NodeId>) -> Result<Self> {
        let n = nodes.len() as u64;
        if n == 0 {
            return Err(Error::Cluster("shard map needs >= 1 node".into()));
        }
        let splits = (1..n).map(|i| i * total_keys.div_ceil(n)).collect();
        ShardMap::new(splits, nodes)
    }

    pub fn num_shards(&self) -> usize {
        self.nodes.len()
    }

    /// Monotone map generation: bumped by every [`ShardMap::split`],
    /// [`ShardMap::merge`], and [`ShardMap::assign`]. Fresh maps start
    /// at 0.
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn nodes(&self) -> &[NodeId] {
        &self.nodes
    }

    /// The shard index owning `key` (binary search over split points).
    /// Unlike [`ShardMap::node_for`], this identifies the *partition*,
    /// not its home node — the replication layer routes by shard index
    /// because a shard's leader node changes on failover while the
    /// partition itself is stable.
    pub fn shard_for(&self, key: u64) -> usize {
        self.splits.partition_point(|&s| s <= key)
    }

    /// The key range `[lo, hi)` owned by shard `shard`; the last shard's
    /// `hi` is `u64::MAX` (open-ended — the curve key `u64::MAX` itself
    /// is unreachable for any real dataset).
    pub fn shard_range(&self, shard: usize) -> (u64, u64) {
        let lo = if shard == 0 { 0 } else { self.splits[shard - 1] };
        let hi = self.splits.get(shard).copied().unwrap_or(u64::MAX);
        (lo, hi)
    }

    /// The node owning `key` (binary search over split points).
    pub fn node_for(&self, key: u64) -> NodeId {
        self.nodes[self.shard_for(key)]
    }

    /// Group sorted `keys` by owning node, preserving order within each
    /// group — the router's batching step. Returns `(node, keys)` pairs
    /// in curve order; for "the vast majority of cutout requests" the
    /// result is a single group (§4.1).
    pub fn route(&self, keys: &[u64]) -> Vec<(NodeId, Vec<u64>)> {
        let mut out: Vec<(NodeId, Vec<u64>)> = Vec::new();
        for &k in keys {
            let node = self.node_for(k);
            match out.last_mut() {
                Some((n, ks)) if *n == node => ks.push(k),
                _ => out.push((node, vec![k])),
            }
        }
        out
    }

    /// Split a contiguous key run `[start, start+len)` into per-shard
    /// sub-runs (runs never straddle a shard boundary after this).
    ///
    /// One binary search locates the starting shard; each further
    /// sub-run advances to the next split point directly, so a wide run
    /// over a many-shard map (what dynamic splitting produces) costs
    /// O(log n + sub-runs), not O(n) per sub-run.
    pub fn route_run(&self, start: u64, len: u64) -> Vec<(NodeId, u64, u64)> {
        let mut out = Vec::new();
        let end = start + len;
        let mut cur = start;
        let mut shard = self.shard_for(start);
        while cur < end {
            let next_split = self.splits.get(shard).copied().unwrap_or(u64::MAX).min(end);
            out.push((self.nodes[shard], cur, next_split - cur));
            cur = next_split;
            shard += 1;
        }
        out
    }

    /// Cut shard `shard` in two at `at_key`: the lower half keeps the
    /// shard index, the upper half becomes shard `shard + 1`, and both
    /// halves stay on the shard's current node (a subsequent
    /// [`ShardMap::assign`] moves one). Returns a new map one version up.
    pub fn split(&self, shard: usize, at_key: u64) -> Result<ShardMap> {
        if shard >= self.num_shards() {
            return Err(Error::Cluster(format!(
                "split: no shard {shard} in a {}-shard map",
                self.num_shards()
            )));
        }
        let (lo, hi) = self.shard_range(shard);
        if at_key <= lo || at_key >= hi {
            return Err(Error::Cluster(format!(
                "split: cut {at_key} outside shard {shard}'s interior ({lo}, {hi})"
            )));
        }
        let mut splits = self.splits.clone();
        splits.insert(shard, at_key);
        let mut nodes = self.nodes.clone();
        nodes.insert(shard + 1, self.nodes[shard]);
        Ok(ShardMap { splits, nodes, version: self.version + 1 })
    }

    /// Merge adjacent shards `i` and `i + 1` back into one shard that
    /// keeps shard `i`'s node (the caller migrates `i + 1`'s keys there
    /// first). Returns a new map one version up.
    pub fn merge(&self, i: usize, j: usize) -> Result<ShardMap> {
        if j != i + 1 || j >= self.num_shards() {
            return Err(Error::Cluster(format!(
                "merge: shards {i} and {j} are not an adjacent pair of a {}-shard map",
                self.num_shards()
            )));
        }
        let mut splits = self.splits.clone();
        splits.remove(i);
        let mut nodes = self.nodes.clone();
        nodes.remove(j);
        Ok(ShardMap { splits, nodes, version: self.version + 1 })
    }

    /// Reassign shard `shard` to `node` — the rebind step of a live
    /// move, after the data has been copied. Returns a new map one
    /// version up.
    pub fn assign(&self, shard: usize, node: NodeId) -> Result<ShardMap> {
        if shard >= self.num_shards() {
            return Err(Error::Cluster(format!(
                "assign: no shard {shard} in a {}-shard map",
                self.num_shards()
            )));
        }
        let mut nodes = self.nodes.clone();
        nodes[shard] = node;
        Ok(ShardMap { splits: self.splits.clone(), nodes, version: self.version + 1 })
    }

    /// Rebalance onto a new node set: returns the new map and the key
    /// ranges that change owner as `(lo, hi, from, to)`. (Data movement
    /// itself is [`crate::storage::migrate`].)
    pub fn rebalance(
        &self,
        total_keys: u64,
        nodes: Vec<NodeId>,
    ) -> Result<(ShardMap, Vec<(u64, u64, NodeId, NodeId)>)> {
        let new = ShardMap::even(total_keys, nodes)?;
        let mut bounds: Vec<u64> = vec![0, total_keys];
        bounds.extend(&self.splits);
        bounds.extend(&new.splits);
        bounds.sort_unstable();
        bounds.dedup();
        let mut moves = Vec::new();
        for w in bounds.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            if lo >= total_keys {
                break;
            }
            let (from, to) = (self.node_for(lo), new.node_for(lo));
            if from != to {
                moves.push((lo, hi.min(total_keys), from, to));
            }
        }
        Ok((new, moves))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::property;

    #[test]
    fn single_routes_everything() {
        let m = ShardMap::single(3);
        assert_eq!(m.node_for(0), 3);
        assert_eq!(m.node_for(u64::MAX), 3);
        assert_eq!(m.route(&[1, 5, 9]), vec![(3, vec![1, 5, 9])]);
    }

    #[test]
    fn even_partition_figure4() {
        // 16 cuboids over 4 nodes, as in Figure 4.
        let m = ShardMap::even(16, vec![0, 1, 2, 3]).unwrap();
        for k in 0..4 {
            assert_eq!(m.node_for(k), 0);
        }
        for k in 4..8 {
            assert_eq!(m.node_for(k), 1);
        }
        for k in 12..16 {
            assert_eq!(m.node_for(k), 3);
        }
    }

    #[test]
    fn invalid_maps_rejected() {
        assert!(ShardMap::new(vec![], vec![]).is_err());
        assert!(ShardMap::new(vec![5], vec![0]).is_err());
        assert!(ShardMap::new(vec![5, 5], vec![0, 1, 2]).is_err());
        assert!(ShardMap::new(vec![9, 5], vec![0, 1, 2]).is_err());
    }

    #[test]
    fn route_groups_contiguously() {
        let m = ShardMap::even(100, vec![10, 20]).unwrap();
        let routed = m.route(&[1, 2, 49, 50, 60, 99]);
        assert_eq!(routed, vec![(10, vec![1, 2, 49]), (20, vec![50, 60, 99])]);
    }

    #[test]
    fn route_run_splits_at_boundaries() {
        let m = ShardMap::even(100, vec![0, 1]).unwrap(); // split at 50
        assert_eq!(m.route_run(40, 20), vec![(0, 40, 10), (1, 50, 10)]);
        assert_eq!(m.route_run(0, 50), vec![(0, 0, 50)]);
        assert_eq!(m.route_run(50, 10), vec![(1, 50, 10)]);
    }

    #[test]
    fn routing_prop_consistent() {
        property("shard_route_consistent", 300, |g| {
            let n_nodes = 1 + g.usize_below(6);
            let total = 1 + g.u64_below(10_000);
            let m = ShardMap::even(total, (0..n_nodes).collect()).unwrap();
            let mut keys = g.vec_u64(32, total);
            keys.sort_unstable();
            let routed = m.route(&keys);
            let mut rebuilt = Vec::new();
            for (node, ks) in &routed {
                for &k in ks {
                    assert_eq!(m.node_for(k), *node);
                    rebuilt.push(k);
                }
            }
            assert_eq!(rebuilt, keys);
        });
    }

    #[test]
    fn route_run_prop_covers_exactly() {
        property("route_run_covers", 300, |g| {
            let total = 16 + g.u64_below(4096);
            let n = 1 + g.usize_below(5);
            let m = ShardMap::even(total, (0..n).collect()).unwrap();
            let start = g.u64_below(total);
            let len = 1 + g.u64_below(total - start);
            let parts = m.route_run(start, len);
            // Parts tile [start, start+len) exactly.
            let mut cur = start;
            for (node, lo, l) in &parts {
                assert_eq!(*lo, cur);
                assert!(*l > 0);
                assert_eq!(m.node_for(*lo), *node);
                assert_eq!(m.node_for(lo + l - 1), *node, "run must stay on one shard");
                cur = lo + l;
            }
            assert_eq!(cur, start + len);
        });
    }

    #[test]
    fn shard_for_and_range_agree() {
        property("shard_range_consistent", 200, |g| {
            let n = 1 + g.usize_below(6);
            let total = n as u64 + g.u64_below(10_000);
            let m = ShardMap::even(total, (0..n).collect()).unwrap();
            for _ in 0..32 {
                let k = g.u64_below(total);
                let s = m.shard_for(k);
                assert_eq!(m.nodes()[s], m.node_for(k));
                let (lo, hi) = m.shard_range(s);
                assert!(lo <= k && (k < hi || hi == u64::MAX));
            }
            // Ranges tile the space in order.
            let mut cur = 0u64;
            for s in 0..m.num_shards() {
                let (lo, hi) = m.shard_range(s);
                assert_eq!(lo, cur);
                assert!(hi > lo);
                cur = hi;
            }
            assert_eq!(cur, u64::MAX);
        });
    }

    #[test]
    fn split_and_merge_round_trip() {
        let m = ShardMap::even(100, vec![0, 1]).unwrap(); // split at 50
        let s = m.split(0, 24).unwrap();
        assert_eq!(s.num_shards(), 3);
        assert_eq!(s.version(), 1);
        // Both halves stay on the old node until an assign moves one.
        assert_eq!(s.node_for(10), 0);
        assert_eq!(s.node_for(30), 0);
        assert_eq!(s.node_for(60), 1);
        assert_eq!(s.shard_range(0), (0, 24));
        assert_eq!(s.shard_range(1), (24, 50));
        let moved = s.assign(1, 2).unwrap();
        assert_eq!(moved.node_for(30), 2);
        assert_eq!(moved.version(), 2);
        // Merging back (after a hypothetical copy home) restores the
        // original partition at a higher version.
        let back = s.merge(0, 1).unwrap();
        assert_eq!(back.num_shards(), 2);
        assert_eq!(back.version(), 2);
        for k in [0u64, 10, 30, 49, 50, 99] {
            assert_eq!(back.node_for(k), m.node_for(k));
            assert_eq!(back.shard_for(k), m.shard_for(k));
        }
    }

    #[test]
    fn split_rejects_out_of_range_cuts() {
        let m = ShardMap::even(100, vec![0, 1]).unwrap();
        assert!(m.split(2, 10).is_err()); // no such shard
        assert!(m.split(0, 0).is_err()); // cut at lo
        assert!(m.split(0, 50).is_err()); // cut at hi (boundary already)
        assert!(m.split(0, 70).is_err()); // cut inside the other shard
        assert!(m.split(1, 50).is_err()); // cut at shard 1's lo
        assert!(m.split(1, 75).is_ok());
    }

    #[test]
    fn merge_rejects_non_adjacent_pairs() {
        let m = ShardMap::even(90, vec![0, 1, 2]).unwrap();
        assert!(m.merge(0, 2).is_err());
        assert!(m.merge(1, 0).is_err());
        assert!(m.merge(2, 3).is_err());
        assert!(m.merge(1, 2).is_ok());
        assert!(m.assign(3, 0).is_err());
    }

    #[test]
    fn route_run_many_shard_map_stays_consistent() {
        // A map splitting has grown to many shards: route_run must agree
        // with the linear reference and tile exactly. (The implementation
        // is one binary search + O(1) per sub-run; this guards the
        // boundary arithmetic, a perf regression shows up in benches.)
        let total: u64 = 1 << 14;
        let n = 512usize;
        let mut m = ShardMap::even(total, (0..4).collect()).unwrap();
        let mut at = Vec::new();
        let width = total / n as u64;
        for i in 1..n as u64 {
            at.push(i * width);
        }
        for k in at {
            let shard = m.shard_for(k);
            let (lo, _) = m.shard_range(shard);
            if k > lo {
                m = m.split(shard, k).unwrap();
            }
        }
        assert_eq!(m.num_shards(), n);
        for (start, len) in [(0u64, total), (37, total - 37), (width - 1, 3 * width), (total - 1, 1)] {
            let parts = m.route_run(start, len);
            let mut cur = start;
            for (node, lo, l) in &parts {
                assert_eq!(*lo, cur);
                assert!(*l > 0);
                assert_eq!(m.node_for(*lo), *node);
                assert_eq!(m.node_for(lo + l - 1), *node);
                cur = lo + l;
            }
            assert_eq!(cur, start + len);
        }
        // A full-space run visits every shard exactly once.
        assert_eq!(m.route_run(0, total).len(), n);
    }

    #[test]
    fn rebalance_single_node_identity() {
        // Single node → single node: nothing moves, split list stays empty.
        let m = ShardMap::even(100, vec![0]).unwrap();
        let (new, moves) = m.rebalance(100, vec![0]).unwrap();
        assert_eq!(new.num_shards(), 1);
        assert!(moves.is_empty());
        // Same-layout rebalance on a multi-node map is also a no-op.
        let two = ShardMap::even(100, vec![0, 1]).unwrap();
        let (_, moves) = two.rebalance(100, vec![0, 1]).unwrap();
        assert!(moves.is_empty());
    }

    #[test]
    fn rebalance_from_empty_split_list_grows() {
        // The unsharded (empty-splits) map growing onto two nodes moves
        // exactly the upper half.
        let m = ShardMap::single(0);
        let (new, moves) = m.rebalance(100, vec![0, 1]).unwrap();
        assert_eq!(new.num_shards(), 2);
        assert_eq!(moves, vec![(50, 100, 0, 1)]);
        // And shrinking back returns it.
        let (one, back) = new.rebalance(100, vec![0]).unwrap();
        assert_eq!(one.num_shards(), 1);
        assert_eq!(back, vec![(50, 100, 1, 0)]);
    }

    #[test]
    fn rebalance_all_keys_on_one_shard() {
        // A degenerate map whose split leaves every live key on shard 0
        // (the second shard owns only keys >= total_keys).
        let m = ShardMap::new(vec![100], vec![0, 1]).unwrap();
        for k in 0..100 {
            assert_eq!(m.node_for(k), 0);
        }
        let (new, moves) = m.rebalance(100, vec![0, 1]).unwrap();
        assert_eq!(moves, vec![(50, 100, 0, 1)]);
        assert_eq!(new.node_for(49), 0);
        assert_eq!(new.node_for(50), 1);
        // Move ranges never extend past the live key space.
        for (lo, hi, _, _) in &moves {
            assert!(lo < hi && *hi <= 100);
        }
    }

    #[test]
    fn rebalance_to_empty_node_set_rejected() {
        let m = ShardMap::even(100, vec![0, 1]).unwrap();
        assert!(m.rebalance(100, vec![]).is_err());
    }

    #[test]
    fn rebalance_moves_cover_changes() {
        let m = ShardMap::even(100, vec![0, 1]).unwrap();
        let (new, moves) = m.rebalance(100, vec![0, 1, 2]).unwrap();
        assert_eq!(new.num_shards(), 3);
        assert!(!moves.is_empty());
        for (lo, hi, from, to) in moves {
            assert_ne!(from, to);
            assert!(lo < hi);
            assert_eq!(m.node_for(lo), from);
            assert_eq!(new.node_for(lo), to);
        }
    }
}
