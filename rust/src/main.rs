//! `ocpd` — leader entrypoint and CLI for the OCP Data Cluster.
//!
//! Subcommands (hand-rolled parser; no clap in the offline vendor set):
//!
//! ```text
//! ocpd serve   [--addr 127.0.0.1:8642] [--db N] [--ssd N] [--replicas R]
//!              [--dims X,Y,Z] [--seed S] [--artifacts DIR]
//!     Boot a cluster with a synthetic EM dataset, start the Web services,
//!     print example URLs, serve until killed.
//!
//! ocpd detect  [--dims X,Y,Z] [--seed S] [--workers N] [--artifacts DIR]
//!     One-shot synapse-detection run (ingest -> detect -> report
//!     precision/recall and throughput).
//!
//! ocpd info    --url http://host:port
//!     Print a remote cluster's project and node info.
//!
//! ocpd wal     [--url http://host:port] [--flush [TOKEN]]
//!     Print every hot project's write-log status (depth, segments,
//!     group-commit batch size, flush lag); with --flush, drain the logs
//!     into their database nodes first.
//!
//! ocpd cache   [--url http://host:port]
//!     Print every project's cuboid-cache status (entries, bytes, hit
//!     rate, evictions, invalidations).
//!
//! ocpd http    [--url http://host:port]
//!     Print the transport status (requests, connection-reuse ratio,
//!     in-flight, 503 rejections, accept errors, per-route latency).
//!
//! ocpd write   [--url http://host:port] [--workers N]
//!     Print every project's write-engine status (fan-out width, elided
//!     vs RMW pre-reads, merge latency); with --workers, retune every
//!     project's write fan-out first.
//!
//! ocpd jobs    [--url http://host:port] [--submit SPEC] [--workers N]
//!              [--job ID] [--dims X,Y,Z] [--seed S] [--cancel ID]
//!     Print every batch job's status. --submit launches a job (SPEC is
//!     the path after /jobs/, e.g. propagate/synapses_v0 or
//!     synapse/synth/synapses_v0 or ingest/synth); --job resumes a
//!     checkpointed id; --cancel stops a running job.
//!
//! ocpd cluster [--url http://host:port] [--failover TOKEN/SHARD]
//!     Print the replication control plane (node health, replica-set
//!     epochs/leaders/lag, failover counters); with --failover, force a
//!     leader promotion on one project shard first.
//!
//! ocpd shards  [--url http://host:port] [--split TOKEN/SHARD] [--auto on|off]
//!     Print every sharded project's topology (shard ranges, owners,
//!     move windows) and the split planner's counters; with --split,
//!     split one shard at its heat median first; with --auto, toggle
//!     heat-driven auto splitting.
//!
//! ocpd metrics [--url http://host:port]
//!     Print the unified Prometheus-text exposition (`GET /metrics/`).
//!
//! ocpd trace   [--url http://host:port] [--slow | --recent]
//!     Print the tracer status; with --slow or --recent, print the
//!     retained span trees instead.
//!
//! ocpd heat    [--url http://host:port] [--account] [--slo]
//!     Print every project's shard heat ranking and top hot key ranges;
//!     with --account the per-tenant ledgers, with --slo the
//!     latency-objective attainment, instead.
//!
//! ocpd loadgen [--url http://host:port] [--token T] [--annotation T]
//!              [--rate R] [--duration S] [--concurrency N[,N...]]
//!              [--hotspot P] [--seed S] [--dims X,Y,Z]
//!              [--mix C,T,W,P] [--deadline-ms MS] [--out FILE]
//!     Open-loop load generator: drive a mixed workload (cutout reads,
//!     tile zooms, annotation writes, job polls) at a fixed arrival
//!     rate, print latency percentiles and 429/503/504/error counts per
//!     scenario, and — with --out — write the BENCH_loadgen.json
//!     report (one run per comma-separated concurrency level).
//!     --deadline-ms stamps X-OCPD-Deadline-Ms on every request; the
//!     server's 504 expiries are counted separately.
//!
//! ocpd qos     [--url http://host:port] [--quota TOKEN] [--req-per-s R]
//!              [--bytes-per-s R] [--weight W] [--enforce on|off]
//!              [--high-water BYTES]
//!     Print the QoS admission/fair-sharing status (enforcement state,
//!     in-flight bytes, throttle/shed/preemption counters, per-tenant
//!     quotas and token levels). --quota sets one tenant's rates and
//!     scheduling weight first; --enforce toggles enforcement.
//! ```
//!
//! Data output goes to stdout; server-side events (boot progress,
//! errors) go through the leveled [`ocpd::obs::log`] macros to stderr
//! (`OCPD_LOG` filters them).

use std::collections::HashMap;
use std::sync::Arc;

use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project};
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::runtime::{artifact_dir, Runtime};
use ocpd::vision::{precision_recall, SynapsePipeline};
use ocpd::{log_error, log_info};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    flags
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn parse_dims(flags: &HashMap<String, String>, default: [u64; 3]) -> [u64; 3] {
    flags
        .get("dims")
        .and_then(|s| {
            let v: Vec<u64> = s.split(',').filter_map(|p| p.parse().ok()).collect();
            (v.len() == 3).then(|| [v[0], v[1], v[2]])
        })
        .unwrap_or(default)
}

/// Boot a cluster with one synthetic image project + one annotation
/// project, ingested and ready.
fn boot(
    dims: [u64; 3],
    seed: u64,
    n_db: usize,
    n_ssd: usize,
    replicas: usize,
) -> ocpd::Result<(Arc<Cluster>, Vec<[u64; 3]>)> {
    let cluster = Cluster::with_config(ocpd::cluster::ClusterConfig {
        n_database: n_db,
        n_ssd,
        replicas,
        monitor: replicas > 1,
        ..ocpd::cluster::ClusterConfig::default()
    });
    cluster.register_dataset(DatasetBuilder::new("synth", dims).levels(3).build());
    let img = cluster.create_image_project(Project::image("synth", "synth"))?;
    cluster.create_annotation_project(Project::annotation("synapses_v0", "synth"), true)?;
    log_info!(target: "serve", "generating synthetic EM volume dims={dims:?} seed={seed}");
    let sv = generate(&SynthSpec::small(dims, seed));
    ingest_volume(&img, &sv.vol, [256, 256, 16])?;
    log_info!(
        target: "serve",
        "ingest complete voxels={} synapses={}",
        sv.vol.len(),
        sv.synapses.len()
    );
    Ok((cluster, sv.synapses))
}

fn cmd_serve(flags: HashMap<String, String>) -> ocpd::Result<()> {
    let addr: String = flag(&flags, "addr", "127.0.0.1:8642".to_string());
    let dims = parse_dims(&flags, [512, 512, 64]);
    let (cluster, _) = boot(
        dims,
        flag(&flags, "seed", 2013),
        flag(&flags, "db", 2usize),
        flag(&flags, "ssd", 1usize),
        flag(&flags, "replicas", 1usize),
    )?;
    let runtime = Runtime::load_dir(
        flags.get("artifacts").map(std::path::PathBuf::from).unwrap_or_else(artifact_dir),
    )
    .ok()
    .map(Arc::new);
    let server = ocpd::web::serve(cluster, runtime, &addr, 16)?;
    log_info!(target: "serve", "ocpd serving at {}", server.url());
    for (method, path) in [
        ("GET", "/info/"),
        ("GET", "/synth/ocpk/0/0,128/0,128/0,16/"),
        ("GET", "/synth/tile/0/4/0_0.gray"),
        ("GET", "/synapses_v0/objects/type/synapse/confidence/geq/0.9/"),
        ("GET", "/wal/status/"),
        ("PUT", "/wal/flush/"),
        ("GET", "/cache/status/"),
        ("GET", "/write/status/"),
        ("GET", "/http/status/"),
        ("GET", "/cluster/status/"),
        ("GET", "/metrics/"),
        ("GET", "/trace/slow/"),
        ("GET", "/heat/status/"),
        ("GET", "/account/status/"),
        ("GET", "/slo/status/"),
        ("GET", "/qos/status/"),
        ("POST", "/jobs/propagate/synapses_v0/"),
        ("GET", "/jobs/status/"),
    ] {
        log_info!(target: "serve", "try: {method} {}{path}", server.url());
    }
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_detect(flags: HashMap<String, String>) -> ocpd::Result<()> {
    let dims = parse_dims(&flags, [512, 512, 32]);
    let seed = flag(&flags, "seed", 2013u64);
    let artifacts =
        flags.get("artifacts").map(std::path::PathBuf::from).unwrap_or_else(artifact_dir);
    let runtime = Arc::new(Runtime::load_dir(&artifacts)?);

    let cluster = Cluster::in_memory(2, 1);
    cluster.register_dataset(DatasetBuilder::new("synth", dims).levels(1).build());
    let img = cluster.create_image_project(Project::image("synth", "synth"))?;
    let anno =
        cluster.create_annotation_project(Project::annotation("synapses_v0", "synth"), true)?;

    log_info!(target: "detect", "generating + ingesting {dims:?}");
    let sv = generate(&SynthSpec::small(dims, seed));
    ingest_volume(&img, &sv.vol, [256, 256, 16])?;

    let mut pipeline = SynapsePipeline::new(runtime, img, anno);
    pipeline.workers = flag(&flags, "workers", 4usize);
    log_info!(target: "detect", "running detector workers={}", pipeline.workers);
    let report = pipeline.run(0, Box3::new([0, 0, 0], dims))?;
    let (p, r, m) = precision_recall(&report.detections, &sv.synapses, 6.0);
    println!("blocks:            {}", report.blocks);
    println!("detections:        {}", report.detections.len());
    println!("ground truth:      {}", sv.synapses.len());
    println!("matches:           {m}");
    println!("precision:         {p:.3}");
    println!("recall:            {r:.3}");
    println!("wall:              {:.2}s", report.wall_secs);
    println!("cutout read:       {:.1} MB/s", report.read_mbps);
    println!("synapse writes:    {:.1} obj/s", report.objects_per_sec);
    Ok(())
}

fn cmd_info(flags: HashMap<String, String>) -> ocpd::Result<()> {
    let url: String = flag(&flags, "url", "http://127.0.0.1:8642".to_string());
    print!("{}", ocpd::client::cluster_info(&url)?);
    Ok(())
}

fn cmd_wal(flags: HashMap<String, String>) -> ocpd::Result<()> {
    let url: String = flag(&flags, "url", "http://127.0.0.1:8642".to_string());
    if let Some(v) = flags.get("flush") {
        let token = if v == "true" { None } else { Some(v.as_str()) };
        println!("{}", ocpd::client::wal_flush(&url, token)?);
    }
    print!("{}", ocpd::client::wal_status(&url)?);
    Ok(())
}

fn cmd_cache(flags: HashMap<String, String>) -> ocpd::Result<()> {
    let url: String = flag(&flags, "url", "http://127.0.0.1:8642".to_string());
    print!("{}", ocpd::client::cache_status(&url)?);
    Ok(())
}

fn cmd_http(flags: HashMap<String, String>) -> ocpd::Result<()> {
    let url: String = flag(&flags, "url", "http://127.0.0.1:8642".to_string());
    print!("{}", ocpd::client::http_status(&url)?);
    Ok(())
}

fn cmd_write(flags: HashMap<String, String>) -> ocpd::Result<()> {
    let url: String = flag(&flags, "url", "http://127.0.0.1:8642".to_string());
    if let Some(n) = flags.get("workers") {
        let n = n
            .parse()
            .map_err(|_| ocpd::Error::BadRequest(format!("bad worker count '{n}'")))?;
        println!("{}", ocpd::client::set_write_workers(&url, n)?);
    }
    print!("{}", ocpd::client::write_status(&url)?);
    Ok(())
}

fn cmd_cluster(flags: HashMap<String, String>) -> ocpd::Result<()> {
    let url: String = flag(&flags, "url", "http://127.0.0.1:8642".to_string());
    if let Some(spec) = flags.get("failover") {
        let parsed =
            spec.split_once('/').and_then(|(t, s)| s.parse::<usize>().ok().map(|n| (t, n)));
        let (token, shard) = parsed.ok_or_else(|| {
            ocpd::Error::BadRequest(format!("bad failover spec '{spec}' (want TOKEN/SHARD)"))
        })?;
        println!("{}", ocpd::client::cluster_failover(&url, token, shard)?);
    }
    print!("{}", ocpd::client::cluster_status(&url)?);
    Ok(())
}

fn cmd_shards(flags: HashMap<String, String>) -> ocpd::Result<()> {
    let url: String = flag(&flags, "url", "http://127.0.0.1:8642".to_string());
    if let Some(spec) = flags.get("split") {
        let parsed =
            spec.split_once('/').and_then(|(t, s)| s.parse::<usize>().ok().map(|n| (t, n)));
        let (token, shard) = parsed.ok_or_else(|| {
            ocpd::Error::BadRequest(format!("bad split spec '{spec}' (want TOKEN/SHARD)"))
        })?;
        println!("{}", ocpd::client::shards_split(&url, token, shard)?);
    }
    if let Some(mode) = flags.get("auto") {
        println!("{}", ocpd::client::shards_auto(&url, mode)?);
    }
    print!("{}", ocpd::client::shards_status(&url)?);
    Ok(())
}

fn cmd_metrics(flags: HashMap<String, String>) -> ocpd::Result<()> {
    let url: String = flag(&flags, "url", "http://127.0.0.1:8642".to_string());
    print!("{}", ocpd::client::metrics(&url)?);
    Ok(())
}

fn cmd_trace(flags: HashMap<String, String>) -> ocpd::Result<()> {
    let url: String = flag(&flags, "url", "http://127.0.0.1:8642".to_string());
    let body = if flags.contains_key("slow") {
        ocpd::client::trace_slow(&url)?
    } else if flags.contains_key("recent") {
        ocpd::client::trace_recent(&url)?
    } else {
        ocpd::client::trace_status(&url)?
    };
    print!("{body}");
    Ok(())
}

fn cmd_heat(flags: HashMap<String, String>) -> ocpd::Result<()> {
    let url: String = flag(&flags, "url", "http://127.0.0.1:8642".to_string());
    let body = if flags.contains_key("account") {
        ocpd::client::account_status(&url)?
    } else if flags.contains_key("slo") {
        ocpd::client::slo_status(&url)?
    } else {
        ocpd::client::heat_status(&url)?
    };
    print!("{body}");
    Ok(())
}

fn cmd_loadgen(flags: HashMap<String, String>) -> ocpd::Result<()> {
    let url: String = flag(&flags, "url", "http://127.0.0.1:8642".to_string());
    let token: String = flag(&flags, "token", "synth".to_string());
    let mut cfg = ocpd::loadgen::LoadgenConfig::new(&url, &token);
    cfg.annotation_token = flags.get("annotation").cloned();
    cfg.dims = parse_dims(&flags, cfg.dims);
    cfg.rate = flag(&flags, "rate", cfg.rate);
    cfg.duration = std::time::Duration::from_secs_f64(flag(&flags, "duration", 5.0));
    cfg.seed = flag(&flags, "seed", cfg.seed);
    cfg.hotspot = flag(&flags, "hotspot", cfg.hotspot);
    if let Some(ms) = flags.get("deadline-ms") {
        let ms = ms
            .parse::<u64>()
            .ok()
            .filter(|&ms| ms > 0)
            .ok_or_else(|| ocpd::Error::BadRequest(format!("bad deadline-ms '{ms}'")))?;
        cfg.deadline_ms = Some(ms);
    }
    if let Some(mix) = flags.get("mix") {
        let v: Vec<u32> = mix.split(',').filter_map(|p| p.parse().ok()).collect();
        if v.len() != 4 {
            return Err(ocpd::Error::BadRequest(format!(
                "bad mix '{mix}' (want CUTOUT,TILE,WRITE,POLL weights)"
            )));
        }
        cfg.mix =
            ocpd::loadgen::ScenarioMix { cutout: v[0], tile: v[1], write: v[2], poll: v[3] };
    }
    let levels: Vec<usize> = flags
        .get("concurrency")
        .map(|s| s.split(',').filter_map(|p| p.parse().ok()).collect())
        .unwrap_or_else(|| vec![cfg.concurrency]);
    if levels.is_empty() {
        return Err(ocpd::Error::BadRequest("bad concurrency list".into()));
    }
    let mut runs = Vec::new();
    for c in levels {
        cfg.concurrency = c;
        let report = ocpd::loadgen::run(&cfg)?;
        print!("{}", report.render_text());
        runs.push(report);
    }
    if let Some(out) = flags.get("out") {
        let json = ocpd::loadgen::render_report_json(
            &cfg,
            &runs,
            "measured by ocpd loadgen against a live server",
        );
        std::fs::write(out, json)
            .map_err(|e| ocpd::Error::Other(format!("writing {out}: {e}")))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_qos(flags: HashMap<String, String>) -> ocpd::Result<()> {
    let url: String = flag(&flags, "url", "http://127.0.0.1:8642".to_string());
    if let Some(token) = flags.get("quota") {
        let mut params = String::new();
        for (flag_key, body_key) in
            [("req-per-s", "req_per_s"), ("bytes-per-s", "bytes_per_s"), ("weight", "weight")]
        {
            if let Some(v) = flags.get(flag_key) {
                params.push_str(&format!("{body_key}={v} "));
            }
        }
        println!("{}", ocpd::client::qos_set_quota(&url, token, &params)?);
    }
    if let Some(mode) = flags.get("enforce") {
        let hw = flags
            .get("high-water")
            .map(|v| {
                v.parse::<u64>()
                    .map_err(|_| ocpd::Error::BadRequest(format!("bad high-water '{v}'")))
            })
            .transpose()?;
        println!("{}", ocpd::client::qos_enforce(&url, mode, hw)?);
    }
    print!("{}", ocpd::client::qos_status(&url)?);
    Ok(())
}

fn cmd_jobs(flags: HashMap<String, String>) -> ocpd::Result<()> {
    let url: String = flag(&flags, "url", "http://127.0.0.1:8642".to_string());
    if let Some(id) = flags.get("cancel") {
        let id = id
            .parse()
            .map_err(|_| ocpd::Error::BadRequest(format!("bad job id '{id}'")))?;
        println!("{}", ocpd::client::cancel_job(&url, id)?);
    }
    if let Some(spec) = flags.get("submit") {
        // Assemble the key=value body from the pass-through flags.
        let mut params = String::new();
        for key in ["workers", "job", "dims", "seed", "block", "res"] {
            if let Some(v) = flags.get(key) {
                params.push_str(&format!("{key}={v} "));
            }
        }
        println!("{}", ocpd::client::submit_job(&url, spec, &params)?);
    }
    print!("{}", ocpd::client::job_status(&url, None)?);
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!(
                "usage: ocpd <serve|detect|info|wal|cache|write|jobs|http|cluster|shards\
                 |metrics|trace|heat|qos|loadgen> [flags]"
            );
            std::process::exit(2);
        }
    };
    let flags = parse_flags(&rest);
    let result = match cmd {
        "serve" => cmd_serve(flags),
        "detect" => cmd_detect(flags),
        "info" => cmd_info(flags),
        "wal" => cmd_wal(flags),
        "cache" => cmd_cache(flags),
        "http" => cmd_http(flags),
        "write" => cmd_write(flags),
        "jobs" => cmd_jobs(flags),
        "cluster" => cmd_cluster(flags),
        "shards" => cmd_shards(flags),
        "metrics" => cmd_metrics(flags),
        "trace" => cmd_trace(flags),
        "heat" => cmd_heat(flags),
        "qos" => cmd_qos(flags),
        "loadgen" => cmd_loadgen(flags),
        other => {
            eprintln!(
                "unknown command '{other}' \
                 (want serve|detect|info|wal|cache|write|jobs|http|cluster|shards|metrics\
                 |trace|heat|qos|loadgen)"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        log_error!("{e}");
        std::process::exit(1);
    }
}
