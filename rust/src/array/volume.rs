//! `DenseVolume<T>`: a dense 3-d voxel array with x-fastest layout and
//! box copy-in/copy-out kernels.

use crate::core::{Box3, Vec3};
use crate::{Error, Result};

/// Scalar voxel types storable in volumes. The `as_bytes`/`from_bytes`
/// casts are little-endian (the only platform we target) and alignment-safe
/// because `Vec<T>` allocations are `T`-aligned.
pub trait VoxelScalar: Copy + Default + PartialEq + Send + Sync + 'static {
    const BYTES: usize;
    fn to_f32(self) -> f32;
    fn from_f32(v: f32) -> Self;
}

macro_rules! impl_scalar {
    ($t:ty, $b:expr) => {
        impl VoxelScalar for $t {
            const BYTES: usize = $b;
            #[inline]
            fn to_f32(self) -> f32 {
                self as f32
            }
            #[inline]
            fn from_f32(v: f32) -> Self {
                v as $t
            }
        }
    };
}

impl_scalar!(u8, 1);
impl_scalar!(u16, 2);
impl_scalar!(u32, 4);
impl_scalar!(u64, 8);
impl_scalar!(f32, 4);

/// Axis-aligned plane selector for lower-dimensional projections (§3.3:
/// tiles; §4.2: cutout projections).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Plane {
    /// XY plane at a fixed z (the imaging plane).
    Xy(u64),
    /// XZ plane at a fixed y.
    Xz(u64),
    /// YZ plane at a fixed x.
    Yz(u64),
}

/// A dense 3-d array with dims `[x, y, z]`, x fastest:
/// `idx = x + dims.x * (y + dims.y * z)`.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseVolume<T: VoxelScalar> {
    dims: Vec3,
    data: Vec<T>,
}

impl<T: VoxelScalar> DenseVolume<T> {
    /// Zero-filled volume.
    pub fn zeros(dims: Vec3) -> Self {
        let n = (dims[0] * dims[1] * dims[2]) as usize;
        DenseVolume { dims, data: vec![T::default(); n] }
    }

    /// Wrap existing data (must match dims).
    pub fn from_vec(dims: Vec3, data: Vec<T>) -> Result<Self> {
        if data.len() as u64 != dims[0] * dims[1] * dims[2] {
            return Err(Error::BadRequest(format!(
                "data length {} != dims {:?}",
                data.len(),
                dims
            )));
        }
        Ok(DenseVolume { dims, data })
    }

    pub fn dims(&self) -> Vec3 {
        self.dims
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[T] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    #[inline]
    pub fn index(&self, p: Vec3) -> usize {
        debug_assert!(p[0] < self.dims[0] && p[1] < self.dims[1] && p[2] < self.dims[2]);
        (p[0] + self.dims[0] * (p[1] + self.dims[1] * p[2])) as usize
    }

    #[inline]
    pub fn get(&self, p: Vec3) -> T {
        self.data[self.index(p)]
    }

    #[inline]
    pub fn set(&mut self, p: Vec3, v: T) {
        let i = self.index(p);
        self.data[i] = v;
    }

    /// Is every voxel the default (zero) value? Lazy cuboid allocation
    /// skips storing such cuboids (§3.2).
    pub fn all_zero(&self) -> bool {
        let z = T::default();
        self.data.iter().all(|&v| v == z)
    }

    /// View as raw little-endian bytes (cuboid serialization).
    pub fn as_bytes(&self) -> &[u8] {
        // Safe: T is a plain scalar; allocation is T-aligned; LE target.
        unsafe {
            std::slice::from_raw_parts(
                self.data.as_ptr() as *const u8,
                self.data.len() * T::BYTES,
            )
        }
    }

    /// Rebuild from raw little-endian bytes.
    pub fn from_bytes(dims: Vec3, bytes: &[u8]) -> Result<Self> {
        let n = (dims[0] * dims[1] * dims[2]) as usize;
        if bytes.len() != n * T::BYTES {
            return Err(Error::Codec(format!(
                "byte length {} != {} for dims {:?}",
                bytes.len(),
                n * T::BYTES,
                dims
            )));
        }
        let mut data = vec![T::default(); n];
        unsafe {
            std::ptr::copy_nonoverlapping(
                bytes.as_ptr(),
                data.as_mut_ptr() as *mut u8,
                bytes.len(),
            );
        }
        Ok(DenseVolume { dims, data })
    }

    /// Copy the sub-box `src_box` of `src` into this volume at `dst_lo`.
    /// Inner x-runs are contiguous in both volumes, so each (y, z) line is
    /// one `copy_from_slice` — the cutout-assembly hot kernel.
    pub fn copy_box_from(&mut self, src: &DenseVolume<T>, src_box: Box3, dst_lo: Vec3) {
        let e = src_box.extent();
        debug_assert!(src_box.hi[0] <= src.dims[0] && src_box.hi[1] <= src.dims[1]);
        debug_assert!(src_box.hi[2] <= src.dims[2]);
        debug_assert!(dst_lo[0] + e[0] <= self.dims[0]);
        debug_assert!(dst_lo[1] + e[1] <= self.dims[1]);
        debug_assert!(dst_lo[2] + e[2] <= self.dims[2]);
        let run = e[0] as usize;
        for dz in 0..e[2] {
            let sz = src_box.lo[2] + dz;
            let tz = dst_lo[2] + dz;
            for dy in 0..e[1] {
                let si = src.index([src_box.lo[0], src_box.lo[1] + dy, sz]);
                let ti = self.index([dst_lo[0], dst_lo[1] + dy, tz]);
                self.data[ti..ti + run].copy_from_slice(&src.data[si..si + run]);
            }
        }
    }

    /// Extract the sub-box `b` as a new volume.
    pub fn extract_box(&self, b: Box3) -> DenseVolume<T> {
        let mut out = DenseVolume::zeros(b.extent());
        out.copy_box_from(self, b, [0, 0, 0]);
        out
    }

    /// Fill the sub-box `b` with `v`.
    pub fn fill_box(&mut self, b: Box3, v: T) {
        let run = (b.hi[0] - b.lo[0]) as usize;
        for z in b.lo[2]..b.hi[2] {
            for y in b.lo[1]..b.hi[1] {
                let i = self.index([b.lo[0], y, z]);
                self.data[i..i + run].fill(v);
            }
        }
    }

    /// Extract a 2-d plane as a (width, height, data) triple — the
    /// projection primitive behind tiles and orthogonal views. Width is
    /// the faster-varying axis of the plane.
    pub fn extract_plane(&self, plane: Plane) -> (u64, u64, Vec<T>) {
        match plane {
            Plane::Xy(z) => {
                let (w, h) = (self.dims[0], self.dims[1]);
                let start = self.index([0, 0, z]);
                (w, h, self.data[start..start + (w * h) as usize].to_vec())
            }
            Plane::Xz(y) => {
                let (w, h) = (self.dims[0], self.dims[2]);
                let mut out = Vec::with_capacity((w * h) as usize);
                for z in 0..h {
                    let i = self.index([0, y, z]);
                    out.extend_from_slice(&self.data[i..i + w as usize]);
                }
                (w, h, out)
            }
            Plane::Yz(x) => {
                let (w, h) = (self.dims[1], self.dims[2]);
                let mut out = Vec::with_capacity((w * h) as usize);
                for z in 0..h {
                    for y in 0..w {
                        out.push(self.get([x, y, z]));
                    }
                }
                (w, h, out)
            }
        }
    }

    /// Count voxels equal to `v`.
    pub fn count_eq(&self, v: T) -> u64 {
        self.data.iter().filter(|&&x| x == v).count() as u64
    }

    /// Map every voxel (used by false-coloring and filtering — the
    /// operations the paper accelerates with parallel Cython, §4.2).
    pub fn map_in_place(&mut self, f: impl Fn(T) -> T + Sync) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// The set of distinct non-zero values in the box (the "what objects
    /// are in a region?" primitive, §4.2 — numpy-unique equivalent).
    pub fn unique_nonzero(&self) -> Vec<T>
    where
        T: Ord,
    {
        let mut vs: Vec<T> = self.data.iter().copied().filter(|&v| v != T::default()).collect();
        vs.sort_unstable();
        vs.dedup();
        vs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::property;
    use crate::util::Rng;

    fn random_vol(rng: &mut Rng, dims: Vec3) -> DenseVolume<u32> {
        let n = (dims[0] * dims[1] * dims[2]) as usize;
        DenseVolume::from_vec(dims, (0..n).map(|_| rng.next_u32()).collect()).unwrap()
    }

    #[test]
    fn index_layout_x_fastest() {
        let v = DenseVolume::<u8>::zeros([4, 3, 2]);
        assert_eq!(v.index([0, 0, 0]), 0);
        assert_eq!(v.index([1, 0, 0]), 1);
        assert_eq!(v.index([0, 1, 0]), 4);
        assert_eq!(v.index([0, 0, 1]), 12);
        assert_eq!(v.index([3, 2, 1]), 23);
    }

    #[test]
    fn from_vec_validates() {
        assert!(DenseVolume::<u8>::from_vec([2, 2, 2], vec![0; 7]).is_err());
        assert!(DenseVolume::<u8>::from_vec([2, 2, 2], vec![0; 8]).is_ok());
    }

    #[test]
    fn bytes_roundtrip() {
        let mut rng = Rng::new(1);
        let v = random_vol(&mut rng, [8, 4, 2]);
        let b = v.as_bytes().to_vec();
        assert_eq!(b.len(), 8 * 4 * 2 * 4);
        let w = DenseVolume::<u32>::from_bytes([8, 4, 2], &b).unwrap();
        assert_eq!(v, w);
        assert!(DenseVolume::<u32>::from_bytes([8, 4, 2], &b[1..]).is_err());
    }

    #[test]
    fn extract_then_fill_roundtrip_prop() {
        property("extract_box_matches_get", 200, |g| {
            let dims = [16 + g.u64_below(17), 16 + g.u64_below(17), 4 + g.u64_below(5)];
            let mut rng = Rng::new(g.seed);
            let vol = random_vol(&mut rng, dims);
            let (lo, hi) = g.boxed(dims, 12);
            let sub = vol.extract_box(Box3::new(lo, hi));
            for z in 0..sub.dims()[2] {
                for y in 0..sub.dims()[1] {
                    for x in 0..sub.dims()[0] {
                        assert_eq!(
                            sub.get([x, y, z]),
                            vol.get([lo[0] + x, lo[1] + y, lo[2] + z])
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn copy_box_roundtrip_prop() {
        // copy out + copy back = identity on the box.
        property("copy_box_roundtrip", 200, |g| {
            let dims = [24, 24, 8];
            let mut rng = Rng::new(g.seed ^ 0xabc);
            let vol = random_vol(&mut rng, dims);
            let (lo, hi) = g.boxed(dims, 16);
            let b = Box3::new(lo, hi);
            let sub = vol.extract_box(b);
            let mut target = vol.clone();
            target.fill_box(b, 0);
            target.copy_box_from(&sub, Box3::new([0, 0, 0], sub.dims()), lo);
            assert_eq!(target, vol);
        });
    }

    #[test]
    fn fill_box_only_touches_box() {
        let mut v = DenseVolume::<u32>::zeros([8, 8, 4]);
        v.fill_box(Box3::new([2, 2, 1], [5, 6, 3]), 7);
        assert_eq!(v.count_eq(7), 3 * 4 * 2);
        assert_eq!(v.get([2, 2, 1]), 7);
        assert_eq!(v.get([4, 5, 2]), 7);
        assert_eq!(v.get([5, 2, 1]), 0);
        assert_eq!(v.get([1, 2, 1]), 0);
    }

    #[test]
    fn planes_match_direct_indexing() {
        let mut rng = Rng::new(3);
        let vol = random_vol(&mut rng, [5, 6, 7]);
        let (w, h, xy) = vol.extract_plane(Plane::Xy(3));
        assert_eq!((w, h), (5, 6));
        assert_eq!(xy[(2 + 3 * 5) as usize], vol.get([2, 3, 3]));
        let (w, h, xz) = vol.extract_plane(Plane::Xz(2));
        assert_eq!((w, h), (5, 7));
        assert_eq!(xz[(1 + 6 * 5) as usize], vol.get([1, 2, 6]));
        let (w, h, yz) = vol.extract_plane(Plane::Yz(4));
        assert_eq!((w, h), (6, 7));
        assert_eq!(yz[(5 + 6 * 6) as usize], vol.get([4, 5, 6]));
    }

    #[test]
    fn unique_nonzero_sorted() {
        let mut v = DenseVolume::<u32>::zeros([4, 4, 1]);
        v.set([0, 0, 0], 9);
        v.set([1, 0, 0], 3);
        v.set([2, 0, 0], 9);
        assert_eq!(v.unique_nonzero(), vec![3, 9]);
    }

    #[test]
    fn all_zero_detects() {
        let mut v = DenseVolume::<u8>::zeros([4, 4, 4]);
        assert!(v.all_zero());
        v.set([3, 3, 3], 1);
        assert!(!v.all_zero());
    }
}
