//! Dense nd-array support: the in-memory representation of cuboids and
//! cutouts, and the copy routines that assemble cutouts from cuboids.
//!
//! This is the system's memory hot path. The paper's evaluation (§5) finds
//! that "array slicing and assembly for cutout requests keeps all
//! processors fully utilized reorganizing data in memory" — the copy
//! kernels here are therefore written as contiguous x-run `memcpy`s;
//! `benches/bench_cutout.rs` regenerates the figure they reproduce.

mod volume;

pub use volume::{DenseVolume, Plane, VoxelScalar};
