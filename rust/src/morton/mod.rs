//! Morton (z-order) space-filling curve: encode/decode in 2/3/4 dimensions
//! and decomposition of axis-aligned boxes into maximal contiguous runs of
//! the curve.
//!
//! This is the paper's core physical-design decision (§3, Figure 4): every
//! cuboid is keyed by the Morton code of its cuboid-grid coordinates, so
//! any power-of-two aligned subregion is wholly contiguous in the key
//! space, convex reads decompose into few contiguous runs (Moon et al.
//! [23]), and — because codes are non-decreasing in every dimension — the
//! same index works on lower-dimensional subspaces. Time series use the
//! 4-d curve (§3.1); channels are *not* in the index (separate cuboid
//! spaces per channel).
//!
//! ```
//! use ocpd::morton::{encode3, decode3, runs_in_box3};
//!
//! // The curve visits the 2x2x2 neighborhood before moving on...
//! assert_eq!(encode3(0, 0, 0), 0);
//! assert_eq!(encode3(1, 1, 1), 7);
//! // ...so a power-of-two aligned box is one contiguous key run.
//! let runs = runs_in_box3([0, 0, 0], [4, 4, 4]);
//! assert_eq!(runs.len(), 1);
//! assert_eq!(runs[0].len, 64);
//! // Codes round-trip.
//! assert_eq!(decode3(encode3(12, 34, 56)), (12, 34, 56));
//! ```

/// Spread the low 21 bits of `v` so consecutive bits land 3 apart
/// (for 3-d interleave).
#[inline]
fn spread3(v: u64) -> u64 {
    let mut x = v & 0x1f_ffff; // 21 bits
    x = (x | (x << 32)) & 0x1f00000000ffff;
    x = (x | (x << 16)) & 0x1f0000ff0000ff;
    x = (x | (x << 8)) & 0x100f00f00f00f00f;
    x = (x | (x << 4)) & 0x10c30c30c30c30c3;
    x = (x | (x << 2)) & 0x1249249249249249;
    x
}

/// Inverse of [`spread3`].
#[inline]
fn compact3(v: u64) -> u64 {
    let mut x = v & 0x1249249249249249;
    x = (x | (x >> 2)) & 0x10c30c30c30c30c3;
    x = (x | (x >> 4)) & 0x100f00f00f00f00f;
    x = (x | (x >> 8)) & 0x1f0000ff0000ff;
    x = (x | (x >> 16)) & 0x1f00000000ffff;
    x = (x | (x >> 32)) & 0x1f_ffff;
    x
}

/// Spread the low 32 bits of `v` so consecutive bits land 2 apart
/// (for 2-d interleave).
#[inline]
fn spread2(v: u64) -> u64 {
    let mut x = v & 0xffff_ffff;
    x = (x | (x << 16)) & 0x0000ffff0000ffff;
    x = (x | (x << 8)) & 0x00ff00ff00ff00ff;
    x = (x | (x << 4)) & 0x0f0f0f0f0f0f0f0f;
    x = (x | (x << 2)) & 0x3333333333333333;
    x = (x | (x << 1)) & 0x5555555555555555;
    x
}

/// Inverse of [`spread2`].
#[inline]
fn compact2(v: u64) -> u64 {
    let mut x = v & 0x5555555555555555;
    x = (x | (x >> 1)) & 0x3333333333333333;
    x = (x | (x >> 2)) & 0x0f0f0f0f0f0f0f0f;
    x = (x | (x >> 4)) & 0x00ff00ff00ff00ff;
    x = (x | (x >> 8)) & 0x0000ffff0000ffff;
    x = (x | (x >> 16)) & 0xffff_ffff;
    x
}

/// Spread the low 16 bits of `v` so consecutive bits land 4 apart
/// (for 4-d interleave).
#[inline]
fn spread4(v: u64) -> u64 {
    let mut x = v & 0xffff;
    x = (x | (x << 24)) & 0x000000ff000000ff;
    x = (x | (x << 12)) & 0x000f000f000f000f;
    x = (x | (x << 6)) & 0x0303030303030303;
    x = (x | (x << 3)) & 0x1111111111111111;
    x
}

/// Inverse of [`spread4`].
#[inline]
fn compact4(v: u64) -> u64 {
    let mut x = v & 0x1111111111111111;
    x = (x | (x >> 3)) & 0x0303030303030303;
    x = (x | (x >> 6)) & 0x000f000f000f000f;
    x = (x | (x >> 12)) & 0x000000ff000000ff;
    x = (x | (x >> 24)) & 0xffff;
    x
}

/// 2-d Morton encode (x fastest). Supports 32 bits per axis.
#[inline]
pub fn encode2(x: u64, y: u64) -> u64 {
    spread2(x) | (spread2(y) << 1)
}

/// 2-d Morton decode.
#[inline]
pub fn decode2(m: u64) -> (u64, u64) {
    (compact2(m), compact2(m >> 1))
}

/// 3-d Morton encode (x fastest, then y, then z). Supports 21 bits per
/// axis — a 2M-cuboid-per-axis grid, far beyond any current dataset
/// (bock11 at full resolution is ~2^10 cuboids per axis).
///
/// ```
/// assert_eq!(ocpd::morton::encode3(1, 0, 0), 1);
/// assert_eq!(ocpd::morton::encode3(0, 1, 0), 2);
/// assert_eq!(ocpd::morton::encode3(0, 0, 1), 4);
/// assert_eq!(ocpd::morton::encode3(2, 0, 0), 8);
/// ```
#[inline]
pub fn encode3(x: u64, y: u64, z: u64) -> u64 {
    debug_assert!(x < (1 << 21) && y < (1 << 21) && z < (1 << 21));
    spread3(x) | (spread3(y) << 1) | (spread3(z) << 2)
}

/// 3-d Morton decode — the exact inverse of [`encode3`] over its 21-bit
/// domain.
///
/// ```
/// use ocpd::morton::{encode3, decode3};
/// for (x, y, z) in [(0, 0, 0), (7, 1, 3), (1 << 20, 5, (1 << 21) - 1)] {
///     assert_eq!(decode3(encode3(x, y, z)), (x, y, z));
/// }
/// ```
#[inline]
pub fn decode3(m: u64) -> (u64, u64, u64) {
    (compact3(m), compact3(m >> 1), compact3(m >> 2))
}

/// 4-d Morton encode for time-series databases (§3.1): time participates
/// in the curve so that "time history of a small region" queries stay
/// local. 16 bits per axis.
#[inline]
pub fn encode4(x: u64, y: u64, z: u64, t: u64) -> u64 {
    debug_assert!(x < (1 << 16) && y < (1 << 16) && z < (1 << 16) && t < (1 << 16));
    spread4(x) | (spread4(y) << 1) | (spread4(z) << 2) | (spread4(t) << 3)
}

/// 4-d Morton decode.
#[inline]
pub fn decode4(m: u64) -> (u64, u64, u64, u64) {
    (compact4(m), compact4(m >> 1), compact4(m >> 2), compact4(m >> 3))
}

/// A contiguous run `[start, start + len)` of Morton codes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Run {
    pub start: u64,
    pub len: u64,
}

/// Enumerate the Morton codes of every cell in the box `[lo, hi)` (cuboid
/// grid coordinates), sorted ascending. The box is half-open.
pub fn codes_in_box3(lo: [u64; 3], hi: [u64; 3]) -> Vec<u64> {
    let mut out = Vec::with_capacity(
        ((hi[0] - lo[0]) * (hi[1] - lo[1]) * (hi[2] - lo[2])) as usize,
    );
    for z in lo[2]..hi[2] {
        for y in lo[1]..hi[1] {
            for x in lo[0]..hi[0] {
                out.push(encode3(x, y, z));
            }
        }
    }
    out.sort_unstable();
    out
}

/// Decompose sorted Morton codes into maximal contiguous runs. Larger
/// aligned boxes produce fewer, longer runs — the property that turns
/// cutouts into streaming I/O (§5: "larger cutouts intersect larger
/// aligned regions of the Morton-order curve producing larger contiguous
/// I/Os").
pub fn coalesce_runs(sorted_codes: &[u64]) -> Vec<Run> {
    let mut runs = Vec::new();
    let mut iter = sorted_codes.iter().copied();
    let Some(first) = iter.next() else { return runs };
    let mut cur = Run { start: first, len: 1 };
    for c in iter {
        if c == cur.start + cur.len {
            cur.len += 1;
        } else {
            debug_assert!(c > cur.start + cur.len, "codes must be sorted+unique");
            runs.push(cur);
            cur = Run { start: c, len: 1 };
        }
    }
    runs.push(cur);
    runs
}

/// Runs covering the box `[lo, hi)` in one call.
pub fn runs_in_box3(lo: [u64; 3], hi: [u64; 3]) -> Vec<Run> {
    coalesce_runs(&codes_in_box3(lo, hi))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::property;

    #[test]
    fn encode3_known_values() {
        // First cells of the canonical z-order.
        assert_eq!(encode3(0, 0, 0), 0);
        assert_eq!(encode3(1, 0, 0), 1);
        assert_eq!(encode3(0, 1, 0), 2);
        assert_eq!(encode3(1, 1, 0), 3);
        assert_eq!(encode3(0, 0, 1), 4);
        assert_eq!(encode3(1, 1, 1), 7);
        assert_eq!(encode3(2, 0, 0), 8);
    }

    #[test]
    fn encode2_known_values() {
        assert_eq!(encode2(0, 0), 0);
        assert_eq!(encode2(1, 0), 1);
        assert_eq!(encode2(0, 1), 2);
        assert_eq!(encode2(1, 1), 3);
        assert_eq!(encode2(2, 0), 4);
        assert_eq!(encode2(2, 3), 0b1110);
    }

    #[test]
    fn roundtrip3_prop() {
        property("morton3_roundtrip", 2000, |g| {
            let x = g.u64_below(1 << 21);
            let y = g.u64_below(1 << 21);
            let z = g.u64_below(1 << 21);
            assert_eq!(decode3(encode3(x, y, z)), (x, y, z));
        });
    }

    #[test]
    fn roundtrip2_prop() {
        property("morton2_roundtrip", 2000, |g| {
            let x = g.u64_below(1 << 32);
            let y = g.u64_below(1 << 32);
            assert_eq!(decode2(encode2(x, y)), (x, y));
        });
    }

    #[test]
    fn roundtrip4_prop() {
        property("morton4_roundtrip", 2000, |g| {
            let v: Vec<u64> = (0..4).map(|_| g.u64_below(1 << 16)).collect();
            assert_eq!(decode4(encode4(v[0], v[1], v[2], v[3])), (v[0], v[1], v[2], v[3]));
        });
    }

    #[test]
    fn monotone_in_each_dimension_prop() {
        // §3: "cube addresses are strictly non-decreasing in each dimension
        // so that the index works on subspaces".
        property("morton3_monotone", 2000, |g| {
            let x = g.u64_below(1 << 20);
            let y = g.u64_below(1 << 20);
            let z = g.u64_below(1 << 20);
            assert!(encode3(x + 1, y, z) > encode3(x, y, z));
            assert!(encode3(x, y + 1, z) > encode3(x, y, z));
            assert!(encode3(x, y, z + 1) > encode3(x, y, z));
        });
    }

    #[test]
    fn aligned_power_of_two_box_is_single_run() {
        // §3: "any power-of-two aligned subregion is wholly contiguous".
        for log in 0..4u32 {
            let s = 1u64 << log;
            for &(bx, by, bz) in &[(0u64, 0u64, 0u64), (1, 0, 2), (3, 2, 1)] {
                let lo = [bx * s, by * s, bz * s];
                let hi = [lo[0] + s, lo[1] + s, lo[2] + s];
                let runs = runs_in_box3(lo, hi);
                assert_eq!(runs.len(), 1, "box {lo:?}..{hi:?} not one run: {runs:?}");
                assert_eq!(runs[0].len, s * s * s);
            }
        }
    }

    #[test]
    fn runs_cover_box_exactly_prop() {
        property("runs_cover_box", 300, |g| {
            let (lo, hi) = g.boxed([64, 64, 32], 16);
            let codes = codes_in_box3(lo, hi);
            let runs = coalesce_runs(&codes);
            let total: u64 = runs.iter().map(|r| r.len).sum();
            assert_eq!(total, codes.len() as u64);
            // Expand runs and compare to code set.
            let mut expanded = Vec::new();
            for r in &runs {
                expanded.extend(r.start..r.start + r.len);
            }
            assert_eq!(expanded, codes);
            // Runs must be disjoint and ordered.
            for w in runs.windows(2) {
                assert!(w[0].start + w[0].len < w[1].start + 1);
            }
        });
    }

    #[test]
    fn larger_aligned_boxes_give_longer_mean_runs() {
        // The clustering property behind Fig 10(b,c)'s continued scaling.
        let mean_run = |s: u64| {
            let runs = runs_in_box3([0, 0, 0], [s, s, s]);
            (s * s * s) as f64 / runs.len() as f64
        };
        assert!(mean_run(2) >= mean_run(1));
        assert!(mean_run(4) > mean_run(2));
        assert!(mean_run(8) > mean_run(4));
    }

    #[test]
    fn empty_and_unit_boxes() {
        assert!(codes_in_box3([3, 3, 3], [3, 5, 5]).is_empty());
        let runs = runs_in_box3([5, 7, 2], [6, 8, 3]);
        assert_eq!(runs, vec![Run { start: encode3(5, 7, 2), len: 1 }]);
    }

    #[test]
    fn subspace_property_z0_matches_2d() {
        // With z fixed at 0, the 3-d curve visits XY cells in an order
        // consistent with increasing 2-d codes (the "works on subspaces"
        // claim): encode3(x,y,0) is a strictly monotone function of
        // encode2(x,y).
        let mut pairs: Vec<(u64, u64)> = Vec::new();
        for y in 0..8 {
            for x in 0..8 {
                pairs.push((encode2(x, y), encode3(x, y, 0)));
            }
        }
        pairs.sort_unstable();
        for w in pairs.windows(2) {
            assert!(w[0].1 < w[1].1);
        }
    }
}
