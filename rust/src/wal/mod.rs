//! SSD write-absorber: a segmented write-ahead log with group commit, a
//! read-through overlay, and background flush into database nodes.
//!
//! The paper "directs I/O to different systems — reads to parallel disk
//! arrays and writes to solid-state storage — to avoid I/O interference"
//! (§4.1). The seed approximated that by *placing* hot annotation
//! projects wholly on SSD nodes and migrating them once. This subsystem
//! does it properly, as a continuous pipeline:
//!
//! * **Log** — every mutation (cuboid put/delete, RAMON metadata, index
//!   blobs) is framed ([`record`]) with a CRC32 and appended to the
//!   current *segment*, stored as chunk blobs on an SSD-class
//!   [`Engine`]. Segments seal at a size threshold and become immutable.
//! * **Group commit** — concurrent writers park on a condvar while one
//!   leader writes a single chunk + `sync` for everything queued behind
//!   it; under load, dozens of logical writes cost one device commit.
//! * **Overlay** — an in-memory `table → key → value` index of every
//!   unflushed record. Reads consult it first and merge over the base
//!   engine, so readers never observe stale data while writes sit in
//!   the log ([`engine::WalEngine`]).
//! * **Flusher** — a background thread drains sealed segments into the
//!   destination (database-node) engine in Morton-sorted, per-table
//!   batches — turning the vision pipeline's random writes into the
//!   sequential runs the disk arrays want — then truncates the log.
//! * **Recovery** — [`Wal::open`] replays whatever segments the log
//!   engine holds, truncating a torn tail frame, and rebuilds the
//!   overlay, so a crash loses nothing that was group-committed.
//!
//! ```
//! use std::sync::Arc;
//! use ocpd::storage::{Engine, MemStore};
//! use ocpd::wal::{Wal, WalConfig};
//!
//! let log: Engine = Arc::new(MemStore::new());
//! let dest: Engine = Arc::new(MemStore::new());
//! let cfg = WalConfig { background_flush: false, ..WalConfig::default() };
//! let wal = Wal::open("demo", log, Arc::clone(&dest), cfg).unwrap();
//! wal.append(vec![("demo/cub".into(), 7, Some(vec![1, 2, 3]))]).unwrap();
//! assert_eq!(wal.depth(), 1); // absorbed by the log, not yet drained
//! assert!(dest.get("demo/cub", 7).unwrap().is_none());
//! wal.flush_now().unwrap(); // drain into the database node
//! assert!(dest.get("demo/cub", 7).unwrap().is_some());
//! ```

pub mod engine;
pub mod record;

pub use engine::WalEngine;
pub use record::WalRecord;

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::metrics::{Counter, Gauge};
use crate::storage::{Blob, Engine};
use crate::util::codec::{Dec, Enc};
use crate::{Error, Result};

/// Chunk keys pack `(segment << SEG_SHIFT) | chunk_index`.
const SEG_SHIFT: u64 = 20;
const CHUNK_MASK: u64 = (1 << SEG_SHIFT) - 1;
const META_VERSION: u32 = 1;

/// Tuning knobs for one log.
#[derive(Clone, Copy, Debug)]
pub struct WalConfig {
    /// Seal the active segment once it holds this many framed bytes.
    pub segment_bytes: usize,
    /// Extra time a group-commit leader waits before taking the queue —
    /// larger windows coalesce more writers per device commit at the
    /// cost of write latency. Zero (default) still batches naturally:
    /// whatever queues during the previous commit rides the next one.
    pub group_window: Duration,
    /// Background flusher poll period.
    pub flush_interval: Duration,
    /// Spawn the background flusher thread. Benches and deterministic
    /// tests turn this off and call [`Wal::flush_now`] themselves.
    pub background_flush: bool,
}

impl Default for WalConfig {
    fn default() -> Self {
        WalConfig {
            segment_bytes: 1 << 20,
            group_window: Duration::ZERO,
            flush_interval: Duration::from_millis(25),
            background_flush: true,
        }
    }
}

/// Counters exported through `/wal/status` and `ocpd wal`.
#[derive(Debug, Default)]
pub struct WalMetrics {
    /// Records ever appended (including those replayed at open).
    pub appended_records: Counter,
    /// Framed bytes ever appended.
    pub appended_bytes: Counter,
    /// Device commits (one chunk write + sync each).
    pub commit_batches: Counter,
    /// Records carried by those commits — `commit_records /
    /// commit_batches` is the group-commit batch size.
    pub commit_records: Counter,
    /// Segments sealed.
    pub segments_sealed: Counter,
    /// Records drained into the destination engine.
    pub flushed_records: Counter,
    /// Segments drained.
    pub flushed_segments: Counter,
    /// Torn frames dropped during recovery or drain.
    pub truncated_chunks: Counter,
    /// Chunks mirrored to follower log engines.
    pub shipped_chunks: Counter,
    /// Failed follower ships (the follower is marked lagging).
    pub ship_errors: Counter,
    /// Unflushed records currently in the log (log depth).
    pub depth: Gauge,
    /// Unflushed framed bytes currently in the log.
    pub depth_bytes: Gauge,
}

/// Point-in-time summary of one log.
#[derive(Clone, Debug)]
pub struct WalStatus {
    pub scope: String,
    pub depth_records: u64,
    pub depth_bytes: u64,
    pub active_segment: u64,
    pub sealed_segments: u64,
    pub appended_records: u64,
    pub commit_batches: u64,
    pub commit_records: u64,
    pub flushed_records: u64,
    pub durable_lsn: u64,
    /// Age of the oldest unflushed record (approximate).
    pub flush_lag_ms: f64,
    /// Follower log engines mirroring this log.
    pub replicas: usize,
    /// Followers currently marked lagging (missed a ship).
    pub replicas_lagging: usize,
    pub shipped_chunks: u64,
}

impl WalStatus {
    /// Mean records per group commit.
    pub fn mean_batch(&self) -> f64 {
        if self.commit_batches == 0 {
            0.0
        } else {
            self.commit_records as f64 / self.commit_batches as f64
        }
    }
}

#[derive(Clone)]
struct OverlayEntry {
    lsn: u64,
    /// `None` masks the base value (a logged delete).
    value: Option<Blob>,
}

type OverlayMap = HashMap<String, BTreeMap<u64, OverlayEntry>>;

struct WalState {
    next_lsn: u64,
    durable_lsn: u64,
    committing: bool,
    /// Framed records awaiting the next group commit.
    pending: Vec<u8>,
    pending_records: u64,
    pending_last_lsn: u64,
    active_seg: u64,
    next_chunk: u64,
    /// Framed bytes committed into the active segment.
    active_bytes: u64,
}

/// A follower mirror of the log: an SSD-class engine on another node
/// that receives every committed chunk, so a dead log node doesn't take
/// group-committed frames with it.
struct WalFollower {
    engine: Engine,
    /// Set when a ship fails; the follower is skipped until
    /// [`Wal::ship_backlog`] re-mirrors the whole log.
    lagging: AtomicBool,
}

/// One project's write-ahead log: SSD-resident segments + overlay +
/// flusher. Cheap to share (`Arc`); all methods take `&self`.
pub struct Wal {
    scope: String,
    log: Engine,
    dest: Engine,
    /// Follower mirrors (chunk-level log shipping).
    followers: RwLock<Vec<WalFollower>>,
    cfg: WalConfig,
    chunk_table: String,
    meta_table: String,
    state: Mutex<WalState>,
    commit_cv: Condvar,
    overlay: RwLock<OverlayMap>,
    /// Serializes drains (background flusher vs. explicit flush).
    flush_lock: Mutex<()>,
    /// Called with `(table, key)` for every record the flusher applies
    /// to the destination engine — the cuboid cache invalidates here so
    /// a drain can never leave a stale cached value in front of the
    /// database node.
    on_apply: RwLock<Option<Arc<dyn Fn(&str, u64) + Send + Sync>>>,
    /// Append time of the oldest unflushed record (flush-lag probe).
    oldest_pending: Mutex<Option<Instant>>,
    pub metrics: WalMetrics,
    stop: AtomicBool,
    flusher: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Wal {
    /// Open (or create) the log named `scope` on `log` (SSD-class
    /// engine), draining into `dest` (database-node engine). Replays any
    /// existing segments to rebuild the overlay — crash recovery and a
    /// plain reopen are the same code path.
    pub fn open(scope: &str, log: Engine, dest: Engine, cfg: WalConfig) -> Result<Arc<Wal>> {
        let chunk_table = format!("{scope}/wal/log");
        let meta_table = format!("{scope}/wal/meta");

        // Last sealed boundary, if recorded.
        let mut active_seg = match log.get(&meta_table, 0)? {
            Some(b) => {
                let mut d = Dec::new(&b);
                let v = d.u32()?;
                if v != META_VERSION {
                    return Err(Error::Codec(format!("wal meta version {v} unsupported")));
                }
                d.u64()?
            }
            None => 0,
        };

        let keys = log.keys(&chunk_table)?;
        if let Some(&max) = keys.last() {
            // Trust the data over a stale/lost meta blob.
            active_seg = active_seg.max(max >> SEG_SHIFT);
        }

        let mut overlay: OverlayMap = HashMap::new();
        let mut next_lsn = 1u64;
        let mut replayed = 0u64;
        let mut replayed_bytes = 0u64;
        let mut truncated = 0u64;
        let mut next_chunk = 0u64;
        let mut active_bytes = 0u64;
        for &k in &keys {
            let Some(blob) = log.get(&chunk_table, k)? else { continue };
            let d = record::decode_chunk(&blob);
            if !d.clean {
                // Torn tail (crash mid-append): persist the truncation so
                // the next open sees a clean chunk.
                truncated += 1;
                if d.valid_bytes == 0 {
                    log.delete(&chunk_table, k)?;
                } else {
                    log.put(&chunk_table, k, &blob[..d.valid_bytes])?;
                }
            }
            replayed += d.records.len() as u64;
            replayed_bytes += d.valid_bytes as u64;
            for r in d.records {
                if r.lsn >= next_lsn {
                    next_lsn = r.lsn + 1;
                }
                overlay_insert(&mut overlay, r);
            }
            if k >> SEG_SHIFT == active_seg {
                next_chunk = next_chunk.max((k & CHUNK_MASK) + 1);
                active_bytes += d.valid_bytes as u64;
            }
        }

        let wal = Arc::new(Wal {
            scope: scope.to_string(),
            log,
            dest,
            followers: RwLock::new(Vec::new()),
            cfg,
            chunk_table,
            meta_table,
            state: Mutex::new(WalState {
                next_lsn,
                durable_lsn: next_lsn - 1,
                committing: false,
                pending: Vec::new(),
                pending_records: 0,
                pending_last_lsn: 0,
                active_seg,
                next_chunk,
                active_bytes,
            }),
            commit_cv: Condvar::new(),
            overlay: RwLock::new(overlay),
            flush_lock: Mutex::new(()),
            on_apply: RwLock::new(None),
            oldest_pending: Mutex::new(if replayed > 0 { Some(Instant::now()) } else { None }),
            metrics: WalMetrics::default(),
            stop: AtomicBool::new(false),
            flusher: Mutex::new(None),
        });
        wal.metrics.appended_records.add(replayed);
        wal.metrics.appended_bytes.add(replayed_bytes);
        wal.metrics.truncated_chunks.add(truncated);
        wal.metrics.depth.add(replayed);
        wal.metrics.depth_bytes.add(replayed_bytes);

        if wal.cfg.background_flush {
            let weak = Arc::downgrade(&wal);
            let interval = wal.cfg.flush_interval;
            let handle = std::thread::Builder::new()
                .name(format!("ocpd-wal-{scope}"))
                .spawn(move || loop {
                    std::thread::sleep(interval);
                    let Some(wal) = weak.upgrade() else { break };
                    if wal.stop.load(Ordering::Relaxed) {
                        break;
                    }
                    // Sealed segments only; the active segment keeps
                    // absorbing until it seals or someone flushes.
                    let _ = wal.drain_sealed();
                    // Heal any follower that missed a ship.
                    let _ = wal.ship_backlog();
                })
                .map_err(|e| Error::Other(format!("spawn wal flusher: {e}")))?;
            *wal.flusher.lock().unwrap() = Some(handle);
        }
        Ok(wal)
    }

    pub fn scope(&self) -> &str {
        &self.scope
    }

    /// Destination engine (where sealed segments drain).
    pub fn dest(&self) -> &Engine {
        &self.dest
    }

    /// Log engine (where segments live).
    pub fn log_engine(&self) -> &Engine {
        &self.log
    }

    /// Unflushed records currently absorbed by the log.
    pub fn depth(&self) -> u64 {
        self.metrics.depth.get()
    }

    /// Install the flush-apply hook: called with `(table, key)` for
    /// every record a drain applies to the destination engine. The
    /// cluster points this at the project's [`CuboidCache`] so
    /// flush-side invalidation keeps read-your-writes intact for any
    /// reader of the database node.
    ///
    /// [`CuboidCache`]: crate::chunkstore::CuboidCache
    pub fn set_on_apply(&self, hook: Option<Arc<dyn Fn(&str, u64) + Send + Sync>>) {
        *self.on_apply.write().unwrap() = hook;
    }

    // ------------------------------------------------------------------
    // Write path: append + group commit
    // ------------------------------------------------------------------

    /// Append mutations (`value: None` = delete) and block until they are
    /// durable in the log. Concurrent callers are group-committed: one
    /// leader performs a single chunk write + sync for every record
    /// queued behind it. Returns the number of records appended.
    pub fn append(&self, muts: Vec<(String, u64, Option<Vec<u8>>)>) -> Result<u64> {
        if muts.is_empty() {
            return Ok(0);
        }
        let n = muts.len() as u64;
        let mut sp = crate::obs::trace::span("wal", "append");
        sp.tag("records", n.to_string());
        let my_last;
        let mut recs: Vec<WalRecord> = Vec::with_capacity(muts.len());
        {
            let mut st = self.state.lock().unwrap();
            // Retirement check under the state lock: after `shutdown`
            // stores the flag, any append that got in first has its
            // records in `pending`, where the retiring flush's commit
            // barrier is guaranteed to cover them — no window where an
            // acknowledged write can be stranded.
            if self.stop.load(Ordering::Relaxed) {
                return Err(Error::Cluster(format!(
                    "write-ahead log '{}' has been retired",
                    self.scope
                )));
            }
            if self.metrics.depth.get() == 0 {
                *self.oldest_pending.lock().unwrap() = Some(Instant::now());
            }
            for (table, key, value) in muts {
                let lsn = st.next_lsn;
                st.next_lsn += 1;
                let rec = WalRecord { lsn, table, key, value };
                let before = st.pending.len();
                rec.encode_into(&mut st.pending);
                let frame = (st.pending.len() - before) as u64;
                st.pending_records += 1;
                st.pending_last_lsn = lsn;
                self.metrics.appended_records.inc();
                self.metrics.appended_bytes.add(frame);
                self.metrics.depth.add(1);
                self.metrics.depth_bytes.add(frame);
                recs.push(rec);
            }
            my_last = st.pending_last_lsn;
            // Overlay entries must become visible before any higher LSN
            // can be assigned (i.e. within this critical section): if a
            // later write to the same key could be drained before this
            // insert ran, the insert would resurrect the stale value.
            // The overlay write lock is taken only for the cheap insert
            // loop — encoding above never holds it.
            let mut ov = self.overlay.write().unwrap();
            for rec in recs {
                overlay_insert(&mut ov, rec);
            }
        }
        self.commit_until(my_last)?;
        Ok(n)
    }

    /// Make everything appended so far durable (an explicit group-commit
    /// barrier).
    pub fn commit(&self) -> Result<()> {
        let target = {
            let mut st = self.state.lock().unwrap();
            // Wait out an in-flight leader first: it already took records
            // off the queue, and `durable_lsn` does not cover them yet.
            while st.committing {
                st = self.commit_cv.wait(st).unwrap();
            }
            if st.pending_records == 0 { st.durable_lsn } else { st.pending_last_lsn }
        };
        self.commit_until(target)
    }

    fn commit_until(&self, target: u64) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.durable_lsn >= target {
                return Ok(());
            }
            if st.committing {
                st = self.commit_cv.wait(st).unwrap();
                continue;
            }
            // Become the group-commit leader.
            st.committing = true;
            drop(st);
            let mut sp = crate::obs::trace::span("wal", "group_commit");
            if !self.cfg.group_window.is_zero() {
                std::thread::sleep(self.cfg.group_window);
            }
            let (batch, batch_records, batch_last, chunk_key) = {
                let mut st = self.state.lock().unwrap();
                let batch = std::mem::take(&mut st.pending);
                let records = std::mem::take(&mut st.pending_records);
                let last = st.pending_last_lsn;
                let key = (st.active_seg << SEG_SHIFT) | st.next_chunk;
                st.next_chunk += 1;
                (batch, records, last, key)
            };
            sp.tag("batch_records", batch_records.to_string());
            if batch.is_empty() {
                st = self.state.lock().unwrap();
                st.committing = false;
                // Saturating: a concurrent seal may have reset the cursor.
                st.next_chunk = st.next_chunk.saturating_sub(1);
                self.commit_cv.notify_all();
                continue;
            }
            let res = self
                .log
                .put(&self.chunk_table, chunk_key, &batch)
                .and_then(|()| self.log.sync());
            if res.is_ok() {
                // Ship the committed chunk to follower mirrors before
                // acking — still outside the state lock, so shipping
                // never serializes against appends.
                self.ship(&self.chunk_table, chunk_key, &batch, true);
            }
            st = self.state.lock().unwrap();
            st.committing = false;
            match res {
                Ok(()) => {
                    st.durable_lsn = st.durable_lsn.max(batch_last);
                    st.active_bytes += batch.len() as u64;
                    self.metrics.commit_batches.inc();
                    self.metrics.commit_records.add(batch_records);
                    if st.active_bytes >= self.cfg.segment_bytes as u64
                        || st.next_chunk >= CHUNK_MASK
                    {
                        let sealed = self.seal_locked(&mut st);
                        self.commit_cv.notify_all();
                        sealed?;
                    } else {
                        self.commit_cv.notify_all();
                    }
                }
                Err(e) => {
                    // Put the batch back so waiters can retry leadership.
                    let mut restored = batch;
                    restored.extend_from_slice(&st.pending);
                    st.pending = restored;
                    st.pending_records += batch_records;
                    if st.pending_last_lsn < batch_last {
                        st.pending_last_lsn = batch_last;
                    }
                    st.next_chunk = st.next_chunk.saturating_sub(1);
                    self.commit_cv.notify_all();
                    return Err(e);
                }
            }
        }
    }

    /// Seal the active segment: later appends start a fresh one and the
    /// sealed segment becomes eligible for background drain.
    fn seal_locked(&self, st: &mut WalState) -> Result<()> {
        st.active_seg += 1;
        st.next_chunk = 0;
        st.active_bytes = 0;
        let mut e = Enc::new();
        e.u32(META_VERSION).u64(st.active_seg);
        let meta = e.finish();
        self.log.put(&self.meta_table, 0, &meta)?;
        self.log.sync()?;
        self.ship(&self.meta_table, 0, &meta, false);
        self.metrics.segments_sealed.inc();
        Ok(())
    }

    /// Mirror one log blob to every follower that is keeping up. A
    /// failed ship marks the follower lagging — it is skipped until
    /// [`Wal::ship_backlog`] re-mirrors the whole log.
    fn ship(&self, table: &str, key: u64, blob: &[u8], count: bool) {
        let followers = self.followers.read().unwrap();
        for f in followers.iter() {
            if f.lagging.load(Ordering::Relaxed) {
                continue;
            }
            match f.engine.put(table, key, blob).and_then(|()| f.engine.sync()) {
                Ok(()) => {
                    if count {
                        self.metrics.shipped_chunks.inc();
                    }
                }
                Err(_) => {
                    f.lagging.store(true, Ordering::Relaxed);
                    self.metrics.ship_errors.inc();
                }
            }
        }
    }

    /// Register a follower log engine and seed it with the current log
    /// contents. Every subsequent group commit ships its chunk to the
    /// follower, so a [`Wal::open`] against the follower engine rebuilds
    /// the same overlay — group-committed frames survive the log node.
    pub fn add_follower(&self, engine: Engine) -> Result<()> {
        let _g = self.flush_lock.lock().unwrap();
        // Register before seeding: a commit racing the copy ships
        // normally, and both writes are idempotent puts of identical
        // bytes. The flush lock keeps drains from truncating chunks out
        // from under the copy.
        self.followers.write().unwrap().push(WalFollower {
            engine: Arc::clone(&engine),
            lagging: AtomicBool::new(false),
        });
        if let Err(e) = self.copy_log_to(&engine) {
            if let Some(f) = self.followers.read().unwrap().last() {
                f.lagging.store(true, Ordering::Relaxed);
            }
            return Err(e);
        }
        Ok(())
    }

    /// Re-mirror the whole log onto any follower marked lagging (after
    /// a crash + revive). Returns followers healed. The flag clears
    /// before the copy so chunks committed during it ship normally;
    /// idempotent puts make the overlap safe.
    pub fn ship_backlog(&self) -> Result<u64> {
        if !self.followers.read().unwrap().iter().any(|f| f.lagging.load(Ordering::Relaxed)) {
            return Ok(0);
        }
        let _g = self.flush_lock.lock().unwrap();
        let mut healed = 0u64;
        let n = self.followers.read().unwrap().len();
        for i in 0..n {
            let (engine, was_lagging) = {
                let fs = self.followers.read().unwrap();
                (Arc::clone(&fs[i].engine), fs[i].lagging.load(Ordering::Relaxed))
            };
            if !was_lagging {
                continue;
            }
            self.followers.read().unwrap()[i].lagging.store(false, Ordering::Relaxed);
            if let Err(e) = self.copy_log_to(&engine) {
                self.followers.read().unwrap()[i].lagging.store(true, Ordering::Relaxed);
                return Err(e);
            }
            healed += 1;
        }
        Ok(healed)
    }

    /// Mirror meta + every chunk currently in the log onto `engine`,
    /// deleting stale follower chunks (segments drained while it was
    /// down).
    fn copy_log_to(&self, engine: &Engine) -> Result<()> {
        let have = engine.keys(&self.chunk_table)?;
        let want = self.log.keys(&self.chunk_table)?;
        let want_set: BTreeSet<u64> = want.iter().copied().collect();
        let stale: Vec<u64> = have.into_iter().filter(|k| !want_set.contains(k)).collect();
        engine.delete_batch(&self.chunk_table, &stale)?;
        for k in want {
            if let Some(b) = self.log.get(&self.chunk_table, k)? {
                engine.put(&self.chunk_table, k, &b)?;
            }
        }
        if let Some(m) = self.log.get(&self.meta_table, 0)? {
            engine.put(&self.meta_table, 0, &m)?;
        }
        engine.sync()
    }

    // ------------------------------------------------------------------
    // Read path: the overlay
    // ------------------------------------------------------------------

    /// Overlay lookup: `None` = not in the log; `Some(None)` = deleted in
    /// the log (masks the base value); `Some(Some(b))` = logged value.
    pub fn overlay_get(&self, table: &str, key: u64) -> Option<Option<Blob>> {
        let ov = self.overlay.read().unwrap();
        ov.get(table).and_then(|m| m.get(&key)).map(|e| e.value.clone())
    }

    /// Overlay entries with keys in `[start, end)`, ascending.
    pub fn overlay_range(&self, table: &str, start: u64, end: u64) -> Vec<(u64, Option<Blob>)> {
        let ov = self.overlay.read().unwrap();
        match ov.get(table) {
            Some(m) => m.range(start..end).map(|(k, e)| (*k, e.value.clone())).collect(),
            None => Vec::new(),
        }
    }

    /// `(live keys, deleted keys)` the overlay holds for `table`.
    pub fn overlay_keys(&self, table: &str) -> (Vec<u64>, Vec<u64>) {
        let ov = self.overlay.read().unwrap();
        let mut live = Vec::new();
        let mut dead = Vec::new();
        if let Some(m) = ov.get(table) {
            for (k, e) in m {
                if e.value.is_some() {
                    live.push(*k);
                } else {
                    dead.push(*k);
                }
            }
        }
        (live, dead)
    }

    /// Tables with at least one unflushed record.
    pub fn overlay_tables(&self) -> Vec<String> {
        let ov = self.overlay.read().unwrap();
        let mut t: Vec<String> = ov.keys().cloned().collect();
        t.sort();
        t
    }

    // ------------------------------------------------------------------
    // Flush path
    // ------------------------------------------------------------------

    /// Drain every *sealed* segment into the destination engine. Runs on
    /// the background flusher; safe to call concurrently with writes.
    /// Returns records applied.
    pub fn drain_sealed(&self) -> Result<u64> {
        let _g = self.flush_lock.lock().unwrap();
        let active = self.state.lock().unwrap().active_seg;
        let mut seg_keys: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
        for k in self.log.keys(&self.chunk_table)? {
            if k >> SEG_SHIFT < active {
                seg_keys.entry(k >> SEG_SHIFT).or_default().push(k);
            }
        }
        let mut total = 0u64;
        for keys in seg_keys.values() {
            total += self.drain_segment(keys)?;
        }
        if total > 0 {
            let mut oldest = self.oldest_pending.lock().unwrap();
            *oldest = if self.metrics.depth.get() == 0 { None } else { Some(Instant::now()) };
        }
        Ok(total)
    }

    /// Force everything — pending, active, sealed — down to the
    /// destination engine. Returns records applied. This is the
    /// `/wal/flush` endpoint and the project-migration primitive.
    pub fn flush_now(&self) -> Result<u64> {
        self.commit()?;
        {
            let mut st = self.state.lock().unwrap();
            // Never seal under a leader's feet: it has a chunk key in the
            // old segment in hand, and resetting the cursor while its
            // write is in flight could reuse a durable chunk key.
            while st.committing {
                st = self.commit_cv.wait(st).unwrap();
            }
            if st.active_bytes > 0 || st.next_chunk > 0 {
                self.seal_locked(&mut st)?;
            }
        }
        self.drain_sealed()
    }

    /// Apply one sealed segment: last-write-wins per key, Morton-sorted
    /// per-table batches to the destination, then truncate the log.
    fn drain_segment(&self, keys: &[u64]) -> Result<u64> {
        let mut records: Vec<WalRecord> = Vec::new();
        let mut chunk_bytes = 0u64;
        for &k in keys {
            if let Some(blob) = self.log.get(&self.chunk_table, k)? {
                chunk_bytes += blob.len() as u64;
                let d = record::decode_chunk(&blob);
                if !d.clean {
                    self.metrics.truncated_chunks.inc();
                }
                records.extend(d.records);
            }
        }
        let n_records = records.len() as u64;

        // Collapse to the newest record per (table, key).
        let mut by_table: HashMap<String, BTreeMap<u64, WalRecord>> = HashMap::new();
        for r in records {
            let slot = by_table.entry(r.table.clone()).or_default();
            match slot.get(&r.key) {
                Some(prev) if prev.lsn > r.lsn => {}
                _ => {
                    slot.insert(r.key, r);
                }
            }
        }
        let mut items: Vec<(String, BTreeMap<u64, WalRecord>)> = by_table.into_iter().collect();
        items.sort_by(|a, b| a.0.cmp(&b.0));

        let on_apply = self.on_apply.read().unwrap().clone();
        for (table, entries) in items {
            let mut puts: Vec<(u64, Vec<u8>)> = Vec::new();
            let mut dels: Vec<u64> = Vec::new();
            let mut applied: Vec<(u64, u64)> = Vec::with_capacity(entries.len());
            for (key, rec) in entries {
                applied.push((key, rec.lsn));
                match rec.value {
                    Some(v) => puts.push((key, v)),
                    None => dels.push(key),
                }
            }
            // BTreeMap iteration is ascending, so `puts` is already the
            // Morton-sorted sequential run the destination wants.
            if !puts.is_empty() {
                self.dest.put_batch(&table, &puts)?;
            }
            for k in dels {
                self.dest.delete(&table, k)?;
            }
            // Invalidate caches in front of the destination before the
            // overlay entries come out, so no read window exists where a
            // stale cached value masks the freshly-applied one.
            if let Some(hook) = &on_apply {
                for (key, _) in &applied {
                    hook(&table, *key);
                }
            }
            // Drop overlay entries this apply made redundant. A newer
            // write sitting in a later (possibly active) segment keeps
            // its overlay entry — its lsn is higher.
            let mut ov = self.overlay.write().unwrap();
            if let Some(map) = ov.get_mut(&table) {
                for (key, lsn) in applied {
                    if let Some(e) = map.get(&key) {
                        if e.lsn <= lsn {
                            map.remove(&key);
                        }
                    }
                }
                if map.is_empty() {
                    ov.remove(&table);
                }
            }
        }

        // The segment is applied; truncate it from the log.
        for &k in keys {
            self.log.delete(&self.chunk_table, k)?;
        }
        self.log.sync()?;
        // Truncate follower mirrors too; a failure just marks the
        // follower lagging (ship_backlog re-mirrors it later).
        {
            let followers = self.followers.read().unwrap();
            for f in followers.iter() {
                if f.lagging.load(Ordering::Relaxed) {
                    continue;
                }
                if f.engine.delete_batch(&self.chunk_table, keys).is_err()
                    || f.engine.sync().is_err()
                {
                    f.lagging.store(true, Ordering::Relaxed);
                    self.metrics.ship_errors.inc();
                }
            }
        }
        self.metrics.flushed_records.add(n_records);
        self.metrics.flushed_segments.inc();
        self.metrics.depth.sub(n_records);
        self.metrics.depth_bytes.sub(chunk_bytes);
        Ok(n_records)
    }

    /// Stop the background flusher (idempotent). Pending data stays in
    /// the log for the next [`Wal::open`] — dropping a `Wal` is always
    /// crash-consistent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.flusher.lock().unwrap().take() {
            // The flusher itself may hold the last `Arc<Wal>`, making it
            // the thread that runs Drop → shutdown: never join yourself
            // (the thread exits on its own once its upgrade fails).
            if h.thread().id() != std::thread::current().id() {
                let _ = h.join();
            }
        }
    }

    // ------------------------------------------------------------------
    // Observability
    // ------------------------------------------------------------------

    pub fn status(&self) -> Result<WalStatus> {
        let (active, durable) = {
            let st = self.state.lock().unwrap();
            (st.active_seg, st.durable_lsn)
        };
        let mut sealed: BTreeSet<u64> = BTreeSet::new();
        for k in self.log.keys(&self.chunk_table)? {
            if k >> SEG_SHIFT < active {
                sealed.insert(k >> SEG_SHIFT);
            }
        }
        let lag_ms = self
            .oldest_pending
            .lock()
            .unwrap()
            .map(|t| t.elapsed().as_secs_f64() * 1e3)
            .unwrap_or(0.0);
        let (replicas, replicas_lagging) = {
            let fs = self.followers.read().unwrap();
            (fs.len(), fs.iter().filter(|f| f.lagging.load(Ordering::Relaxed)).count())
        };
        Ok(WalStatus {
            scope: self.scope.clone(),
            depth_records: self.metrics.depth.get(),
            depth_bytes: self.metrics.depth_bytes.get(),
            active_segment: active,
            sealed_segments: sealed.len() as u64,
            appended_records: self.metrics.appended_records.get(),
            commit_batches: self.metrics.commit_batches.get(),
            commit_records: self.metrics.commit_records.get(),
            flushed_records: self.metrics.flushed_records.get(),
            durable_lsn: durable,
            flush_lag_ms: if self.metrics.depth.get() == 0 { 0.0 } else { lag_ms },
            replicas,
            replicas_lagging,
            shipped_chunks: self.metrics.shipped_chunks.get(),
        })
    }
}

impl Drop for Wal {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn overlay_insert(ov: &mut OverlayMap, rec: WalRecord) {
    let WalRecord { lsn, table, key, value } = rec;
    let slot = ov.entry(table).or_default();
    match slot.get(&key) {
        Some(prev) if prev.lsn > lsn => {}
        _ => {
            slot.insert(key, OverlayEntry { lsn, value: value.map(Arc::new) });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{DeviceProfile, MemStore, SimulatedStore};

    fn quiet_cfg() -> WalConfig {
        WalConfig { background_flush: false, ..WalConfig::default() }
    }

    fn mem_wal(cfg: WalConfig) -> (Arc<Wal>, Engine, Engine) {
        let log: Engine = Arc::new(MemStore::new());
        let dest: Engine = Arc::new(MemStore::new());
        let wal = Wal::open("t", Arc::clone(&log), Arc::clone(&dest), cfg).unwrap();
        (wal, log, dest)
    }

    fn put(table: &str, key: u64, v: &[u8]) -> (String, u64, Option<Vec<u8>>) {
        (table.to_string(), key, Some(v.to_vec()))
    }

    #[test]
    fn append_then_overlay_read() {
        let (wal, _log, dest) = mem_wal(quiet_cfg());
        wal.append(vec![put("tbl", 5, b"five"), put("tbl", 9, b"nine")]).unwrap();
        assert_eq!(**wal.overlay_get("tbl", 5).unwrap().unwrap(), *b"five");
        assert!(wal.overlay_get("tbl", 6).is_none());
        // Nothing reached the destination yet.
        assert_eq!(dest.get("tbl", 5).unwrap(), None);
        assert_eq!(wal.depth(), 2);
    }

    #[test]
    fn delete_masks_base_value() {
        let (wal, _log, dest) = mem_wal(quiet_cfg());
        dest.put("tbl", 1, b"base").unwrap();
        wal.append(vec![("tbl".to_string(), 1, None)]).unwrap();
        assert_eq!(wal.overlay_get("tbl", 1), Some(None));
        // Flush applies the tombstone.
        wal.flush_now().unwrap();
        assert_eq!(dest.get("tbl", 1).unwrap(), None);
    }

    #[test]
    fn flush_moves_everything_morton_sorted() {
        let (wal, log, dest) = mem_wal(quiet_cfg());
        // Append in deliberately random key order.
        for &k in &[9u64, 2, 7, 0, 5, 3] {
            wal.append(vec![put("a/cub", k, &k.to_le_bytes())]).unwrap();
        }
        wal.append(vec![put("b/ramon", 1, b"meta")]).unwrap();
        let moved = wal.flush_now().unwrap();
        assert_eq!(moved, 7);
        assert_eq!(wal.depth(), 0);
        assert_eq!(dest.keys("a/cub").unwrap(), vec![0, 2, 3, 5, 7, 9]);
        assert_eq!(**dest.get("b/ramon", 1).unwrap().unwrap(), *b"meta");
        // Log truncated.
        assert!(log.keys("t/wal/log").unwrap().is_empty());
        // Overlay emptied.
        assert!(wal.overlay_get("a/cub", 9).is_none());
    }

    #[test]
    fn last_write_wins_within_segment() {
        let (wal, _log, dest) = mem_wal(quiet_cfg());
        wal.append(vec![put("tbl", 4, b"old")]).unwrap();
        wal.append(vec![put("tbl", 4, b"new")]).unwrap();
        assert_eq!(**wal.overlay_get("tbl", 4).unwrap().unwrap(), *b"new");
        wal.flush_now().unwrap();
        assert_eq!(**dest.get("tbl", 4).unwrap().unwrap(), *b"new");
    }

    #[test]
    fn reopen_replays_unflushed_records() {
        let log: Engine = Arc::new(MemStore::new());
        let dest: Engine = Arc::new(MemStore::new());
        {
            let wal =
                Wal::open("t", Arc::clone(&log), Arc::clone(&dest), quiet_cfg()).unwrap();
            wal.append(vec![put("tbl", 11, b"eleven")]).unwrap();
            // Dropped without flushing — the simulated crash.
        }
        let wal = Wal::open("t", Arc::clone(&log), Arc::clone(&dest), quiet_cfg()).unwrap();
        assert_eq!(**wal.overlay_get("tbl", 11).unwrap().unwrap(), *b"eleven");
        assert_eq!(wal.depth(), 1);
        // And the replayed record still flushes.
        wal.flush_now().unwrap();
        assert_eq!(**dest.get("tbl", 11).unwrap().unwrap(), *b"eleven");
    }

    #[test]
    fn recovery_truncates_torn_tail() {
        let log: Engine = Arc::new(MemStore::new());
        let dest: Engine = Arc::new(MemStore::new());
        {
            let wal =
                Wal::open("t", Arc::clone(&log), Arc::clone(&dest), quiet_cfg()).unwrap();
            wal.append(vec![put("tbl", 1, b"good")]).unwrap();
            wal.append(vec![put("tbl", 2, b"also good")]).unwrap();
        }
        // Corrupt the tail of the last chunk (torn write at power loss).
        let keys = log.keys("t/wal/log").unwrap();
        let last = *keys.last().unwrap();
        let blob = log.get("t/wal/log", last).unwrap().unwrap();
        let mut torn = (*blob).clone();
        let n = torn.len();
        torn.truncate(n - 3);
        log.put("t/wal/log", last, &torn).unwrap();

        let wal = Wal::open("t", Arc::clone(&log), Arc::clone(&dest), quiet_cfg()).unwrap();
        assert_eq!(wal.metrics.truncated_chunks.get(), 1);
        // Record 1 survived; the torn record 2 is gone.
        assert_eq!(**wal.overlay_get("tbl", 1).unwrap().unwrap(), *b"good");
        assert!(wal.overlay_get("tbl", 2).is_none());
        // New appends continue after the truncation.
        wal.append(vec![put("tbl", 3, b"after")]).unwrap();
        wal.flush_now().unwrap();
        assert_eq!(dest.keys("tbl").unwrap(), vec![1, 3]);
    }

    #[test]
    fn sealing_rolls_segments_and_background_style_drain_applies_them() {
        let cfg = WalConfig { segment_bytes: 256, ..quiet_cfg() };
        let (wal, _log, dest) = mem_wal(cfg);
        for k in 0..32u64 {
            wal.append(vec![put("tbl", k, &[7u8; 40])]).unwrap();
        }
        assert!(wal.metrics.segments_sealed.get() >= 2, "tiny segments must seal");
        // Drain only sealed segments — the active one keeps absorbing.
        let drained = wal.drain_sealed().unwrap();
        assert!(drained > 0);
        assert!(wal.depth() < 32);
        // Overlay still answers for the undrained tail; dest has the rest.
        for k in 0..32u64 {
            let in_overlay = wal.overlay_get("tbl", k).is_some();
            let in_dest = dest.get("tbl", k).unwrap().is_some();
            assert!(in_overlay || in_dest, "key {k} lost");
        }
    }

    #[test]
    fn group_commit_batches_concurrent_writers() {
        let cfg = WalConfig {
            group_window: Duration::from_millis(4),
            ..quiet_cfg()
        };
        let log: Engine = Arc::new(SimulatedStore::new(
            Arc::new(MemStore::new()),
            DeviceProfile::ssd_raid0(),
            0.01,
        ));
        let dest: Engine = Arc::new(MemStore::new());
        let wal = Wal::open("t", log, dest, cfg).unwrap();
        std::thread::scope(|s| {
            for w in 0..8u64 {
                let wal = Arc::clone(&wal);
                s.spawn(move || {
                    for i in 0..5u64 {
                        wal.append(vec![put("tbl", w * 100 + i, &[1u8; 64])]).unwrap();
                    }
                });
            }
        });
        let st = wal.status().unwrap();
        assert_eq!(st.appended_records, 40);
        assert_eq!(st.commit_records, 40);
        assert!(
            st.commit_batches < 40,
            "expected group commit to batch: {} batches",
            st.commit_batches
        );
        assert!(st.mean_batch() > 1.0);
        // Nothing lost.
        wal.flush_now().unwrap();
        assert_eq!(wal.dest().keys("tbl").unwrap().len(), 40);
    }

    #[test]
    fn follower_mirrors_log_and_recovers_the_overlay() {
        let (wal, _log, dest) = mem_wal(quiet_cfg());
        let follower: Engine = Arc::new(MemStore::new());
        wal.add_follower(Arc::clone(&follower)).unwrap();
        wal.append(vec![put("tbl", 1, b"one"), put("tbl", 2, b"two")]).unwrap();
        assert!(wal.metrics.shipped_chunks.get() >= 1, "commit must ship");
        // Open the follower's mirror as its own log: same overlay — the
        // promoted log node resumes exactly where the dead one stopped.
        let dest2: Engine = Arc::new(MemStore::new());
        let recovered =
            Wal::open("t", Arc::clone(&follower), Arc::clone(&dest2), quiet_cfg()).unwrap();
        assert_eq!(**recovered.overlay_get("tbl", 1).unwrap().unwrap(), *b"one");
        assert_eq!(**recovered.overlay_get("tbl", 2).unwrap().unwrap(), *b"two");
        // Drain truncates the mirror too.
        wal.flush_now().unwrap();
        assert!(follower.keys("t/wal/log").unwrap().is_empty(), "mirror not truncated");
        assert!(dest.get("tbl", 1).unwrap().is_some());
        let st = wal.status().unwrap();
        assert_eq!(st.replicas, 1);
        assert_eq!(st.replicas_lagging, 0);
    }

    #[test]
    fn lagging_follower_heals_via_ship_backlog() {
        let (wal, _log, _dest) = mem_wal(quiet_cfg());
        let follower = Arc::new(SimulatedStore::instant(Arc::new(MemStore::new()), 1));
        wal.add_follower(Arc::clone(&follower) as Engine).unwrap();
        wal.append(vec![put("tbl", 1, b"one")]).unwrap();
        follower.faults().crash();
        wal.append(vec![put("tbl", 2, b"two")]).unwrap();
        assert!(wal.metrics.ship_errors.get() >= 1, "crashed follower must miss the ship");
        assert_eq!(wal.status().unwrap().replicas_lagging, 1);
        // Later commits skip the lagging follower entirely.
        let errs = wal.metrics.ship_errors.get();
        wal.append(vec![put("tbl", 3, b"three")]).unwrap();
        assert_eq!(wal.metrics.ship_errors.get(), errs);
        // Revive + backlog ship: the mirror has all three records again.
        follower.faults().revive();
        assert_eq!(wal.ship_backlog().unwrap(), 1);
        assert_eq!(wal.ship_backlog().unwrap(), 0, "healed follower needs nothing");
        assert_eq!(wal.status().unwrap().replicas_lagging, 0);
        let dest2: Engine = Arc::new(MemStore::new());
        let recovered = Wal::open("t", follower, dest2, quiet_cfg()).unwrap();
        for (k, v) in [(1u64, b"one".as_ref()), (2, b"two"), (3, b"three")] {
            assert_eq!(**recovered.overlay_get("tbl", k).unwrap().unwrap(), *v, "key {k}");
        }
    }

    #[test]
    fn status_reports_depth_and_lag() {
        let (wal, _log, _dest) = mem_wal(quiet_cfg());
        let st = wal.status().unwrap();
        assert_eq!(st.depth_records, 0);
        assert_eq!(st.flush_lag_ms, 0.0);
        wal.append(vec![put("tbl", 1, b"x")]).unwrap();
        let st = wal.status().unwrap();
        assert_eq!(st.depth_records, 1);
        assert!(st.depth_bytes > 0);
        wal.flush_now().unwrap();
        let st = wal.status().unwrap();
        assert_eq!(st.depth_records, 0);
        assert_eq!(st.flush_lag_ms, 0.0);
    }
}
