//! WAL record framing: length-prefixed, CRC32-guarded frames holding one
//! logical storage mutation each.
//!
//! Frame layout (all little-endian, [`crate::util::codec`] idioms):
//!
//! ```text
//! [u32 payload_len][u32 crc32(payload)][payload]
//! payload = u8 kind | u64 lsn | str table | u64 key | bytes value?
//! ```
//!
//! `kind` is 1 (put, value present) or 2 (delete, no value). Decoding is
//! prefix-tolerant: a torn tail (crash mid-append) yields the records of
//! the longest valid prefix plus the byte offset where corruption begins,
//! so recovery can truncate rather than refuse to open.

use crate::util::codec::{crc32, Dec};
use crate::{Error, Result};

const KIND_PUT: u8 = 1;
const KIND_DELETE: u8 = 2;

/// LEB128 varint straight into an existing buffer — the same wire
/// format as [`crate::util::codec::Enc::varint`], without the
/// intermediate allocation.
fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            break;
        }
        buf.push(byte | 0x80);
    }
}

/// One logical mutation in the log. `value: None` encodes a delete.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WalRecord {
    /// Log sequence number — totally ordered across the whole log.
    pub lsn: u64,
    /// Destination storage table.
    pub table: String,
    /// Destination key (Morton code, RAMON id, ...).
    pub key: u64,
    /// Payload; `None` is a tombstone.
    pub value: Option<Vec<u8>>,
}

impl WalRecord {
    /// Frame and append this record to `buf`. The payload is written in
    /// place (this runs under the WAL's state lock — no intermediate
    /// buffer) and the length/CRC header backfilled.
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        let header = buf.len();
        buf.extend_from_slice(&[0u8; 8]); // len + crc placeholders
        let payload = buf.len();
        match &self.value {
            Some(v) => {
                buf.push(KIND_PUT);
                buf.extend_from_slice(&self.lsn.to_le_bytes());
                put_varint(buf, self.table.len() as u64);
                buf.extend_from_slice(self.table.as_bytes());
                buf.extend_from_slice(&self.key.to_le_bytes());
                put_varint(buf, v.len() as u64);
                buf.extend_from_slice(v);
            }
            None => {
                buf.push(KIND_DELETE);
                buf.extend_from_slice(&self.lsn.to_le_bytes());
                put_varint(buf, self.table.len() as u64);
                buf.extend_from_slice(self.table.as_bytes());
                buf.extend_from_slice(&self.key.to_le_bytes());
            }
        }
        let len = (buf.len() - payload) as u32;
        let crc = crc32(&buf[payload..]);
        buf[header..header + 4].copy_from_slice(&len.to_le_bytes());
        buf[header + 4..header + 8].copy_from_slice(&crc.to_le_bytes());
    }

    fn decode_payload(payload: &[u8]) -> Result<WalRecord> {
        let mut d = Dec::new(payload);
        let kind = d.u8()?;
        let lsn = d.u64()?;
        let table = d.str()?;
        let key = d.u64()?;
        let value = match kind {
            KIND_PUT => Some(d.bytes()?.to_vec()),
            KIND_DELETE => None,
            k => return Err(Error::Codec(format!("unknown wal record kind {k}"))),
        };
        Ok(WalRecord { lsn, table, key, value })
    }
}

/// Result of decoding a chunk of framed records.
#[derive(Debug)]
pub struct DecodedChunk {
    pub records: Vec<WalRecord>,
    /// Bytes of valid prefix; `< buf.len()` when the tail is torn.
    pub valid_bytes: usize,
    /// True when the whole buffer decoded cleanly.
    pub clean: bool,
}

/// Decode every intact frame in `buf`, stopping (not failing) at the
/// first incomplete or corrupt frame.
pub fn decode_chunk(buf: &[u8]) -> DecodedChunk {
    let mut records = Vec::new();
    let mut pos = 0usize;
    while buf.len() - pos >= 8 {
        let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(buf[pos + 4..pos + 8].try_into().unwrap());
        let Some(end) = pos.checked_add(8).and_then(|p| p.checked_add(len)) else { break };
        if end > buf.len() {
            break; // truncated frame
        }
        let payload = &buf[pos + 8..end];
        if crc32(payload) != crc {
            break; // torn or corrupt
        }
        match WalRecord::decode_payload(payload) {
            Ok(r) => records.push(r),
            Err(_) => break,
        }
        pos = end;
    }
    DecodedChunk { clean: pos == buf.len(), records, valid_bytes: pos }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(lsn: u64, key: u64, value: Option<&[u8]>) -> WalRecord {
        WalRecord {
            lsn,
            table: "proj/cub/r0/c0".into(),
            key,
            value: value.map(|v| v.to_vec()),
        }
    }

    #[test]
    fn roundtrip_put_and_delete() {
        let mut buf = Vec::new();
        rec(1, 42, Some(b"hello")).encode_into(&mut buf);
        rec(2, 42, None).encode_into(&mut buf);
        let d = decode_chunk(&buf);
        assert!(d.clean);
        assert_eq!(d.records.len(), 2);
        assert_eq!(d.records[0], rec(1, 42, Some(b"hello")));
        assert_eq!(d.records[1], rec(2, 42, None));
    }

    #[test]
    fn torn_tail_truncates_to_valid_prefix() {
        let mut buf = Vec::new();
        rec(1, 7, Some(b"aaaa")).encode_into(&mut buf);
        let good = buf.len();
        rec(2, 8, Some(b"bbbb")).encode_into(&mut buf);
        // Tear the second frame mid-payload (crash mid-append).
        buf.truncate(good + 10);
        let d = decode_chunk(&buf);
        assert!(!d.clean);
        assert_eq!(d.valid_bytes, good);
        assert_eq!(d.records.len(), 1);
        assert_eq!(d.records[0].key, 7);
    }

    #[test]
    fn bit_flip_detected_by_crc() {
        let mut buf = Vec::new();
        rec(1, 7, Some(b"payload")).encode_into(&mut buf);
        let n = buf.len();
        buf[n - 2] ^= 0x40;
        let d = decode_chunk(&buf);
        assert!(!d.clean);
        assert!(d.records.is_empty());
        assert_eq!(d.valid_bytes, 0);
    }

    #[test]
    fn garbage_header_is_not_a_panic() {
        let d = decode_chunk(&[0xff; 6]);
        assert!(!d.clean);
        assert!(d.records.is_empty());
        // Absurd length field must not overflow or allocate.
        let mut buf = vec![0xffu8, 0xff, 0xff, 0xff];
        buf.extend_from_slice(&[0u8; 12]);
        let d = decode_chunk(&buf);
        assert!(d.records.is_empty());
    }

    #[test]
    fn empty_chunk_is_clean() {
        let d = decode_chunk(&[]);
        assert!(d.clean);
        assert_eq!(d.valid_bytes, 0);
    }
}
