//! [`WalEngine`]: a [`StorageEngine`] that routes every mutation through
//! a [`Wal`] and serves reads by merging the WAL's unflushed overlay over
//! the base (destination) engine.
//!
//! This is how the write-absorber plugs into the rest of the stack with
//! zero changes to [`crate::chunkstore`], [`crate::cutout`] or
//! [`crate::annotation`]: the cluster hands a hot project a `WalEngine`
//! instead of a raw node engine, and every cuboid, index, exception and
//! RAMON table write becomes a durable log append while reads stay
//! consistent (read-your-writes through the overlay).

use std::sync::Arc;

use crate::storage::{Blob, Engine, IoStats, StorageEngine};
use crate::wal::Wal;
use crate::Result;

/// Write-through-log view over `wal.dest()`.
pub struct WalEngine {
    wal: Arc<Wal>,
    stats: IoStats,
}

impl WalEngine {
    pub fn new(wal: Arc<Wal>) -> Self {
        WalEngine { wal, stats: IoStats::default() }
    }

    pub fn wal(&self) -> &Arc<Wal> {
        &self.wal
    }

    fn base(&self) -> &Engine {
        self.wal.dest()
    }
}

impl StorageEngine for WalEngine {
    fn name(&self) -> &str {
        "wal"
    }

    fn get(&self, table: &str, key: u64) -> Result<Option<Blob>> {
        let v = match self.wal.overlay_get(table, key) {
            Some(Some(b)) => Some(b),
            Some(None) => None, // logged delete masks the base value
            None => self.base().get(table, key)?,
        };
        match &v {
            Some(b) => self.stats.record_read(b.len()),
            None => self.stats.record_miss(),
        }
        Ok(v)
    }

    fn put(&self, table: &str, key: u64, value: &[u8]) -> Result<()> {
        self.stats.record_write(value.len());
        self.wal.append(vec![(table.to_string(), key, Some(value.to_vec()))])?;
        Ok(())
    }

    fn delete(&self, table: &str, key: u64) -> Result<()> {
        self.wal.append(vec![(table.to_string(), key, None)])?;
        Ok(())
    }

    /// N tombstones become one group-commit log append (the delete-side
    /// twin of `put_batch`).
    fn delete_batch(&self, table: &str, keys: &[u64]) -> Result<()> {
        if keys.is_empty() {
            return Ok(());
        }
        let muts: Vec<(String, u64, Option<Vec<u8>>)> =
            keys.iter().map(|&k| (table.to_string(), k, None)).collect();
        self.wal.append(muts)?;
        Ok(())
    }

    fn get_batch(&self, table: &str, keys: &[u64]) -> Result<Vec<Option<Blob>>> {
        // Resolve what the overlay can; fetch the rest in one base batch.
        let mut out: Vec<Option<Option<Blob>>> = Vec::with_capacity(keys.len());
        let mut missing: Vec<u64> = Vec::new();
        let mut missing_at: Vec<usize> = Vec::new();
        for (i, &k) in keys.iter().enumerate() {
            match self.wal.overlay_get(table, k) {
                Some(hit) => out.push(Some(hit)),
                None => {
                    out.push(None);
                    missing.push(k);
                    missing_at.push(i);
                }
            }
        }
        if !missing.is_empty() {
            let fetched = self.base().get_batch(table, &missing)?;
            for (i, v) in missing_at.into_iter().zip(fetched) {
                out[i] = Some(v);
            }
        }
        let resolved: Vec<Option<Blob>> =
            out.into_iter().map(|v| v.expect("all slots resolved")).collect();
        for v in &resolved {
            match v {
                Some(b) => self.stats.record_read(b.len()),
                None => self.stats.record_miss(),
            }
        }
        Ok(resolved)
    }

    /// One group commit for the whole batch — this is where the
    /// write-absorber earns its keep: a cuboid batch that would be N
    /// random device writes becomes one log append.
    fn put_batch(&self, table: &str, items: &[(u64, Vec<u8>)]) -> Result<()> {
        let muts: Vec<(String, u64, Option<Vec<u8>>)> = items
            .iter()
            .map(|(k, v)| {
                self.stats.record_write(v.len());
                (table.to_string(), *k, Some(v.clone()))
            })
            .collect();
        self.wal.append(muts)?;
        Ok(())
    }

    fn get_run(&self, table: &str, start: u64, len: u64) -> Result<Vec<(u64, Blob)>> {
        self.stats.record_run_read();
        let end = start.saturating_add(len);
        let base = self.base().get_run(table, start, len)?;
        let over = self.wal.overlay_range(table, start, end);
        if over.is_empty() {
            for (_, b) in &base {
                self.stats.record_read(b.len());
            }
            return Ok(base);
        }
        // Merge: overlay wins per key; logged deletes drop base entries.
        let mut merged: std::collections::BTreeMap<u64, Blob> = base.into_iter().collect();
        for (k, v) in over {
            match v {
                Some(b) => {
                    merged.insert(k, b);
                }
                None => {
                    merged.remove(&k);
                }
            }
        }
        let out: Vec<(u64, Blob)> = merged.into_iter().collect();
        for (_, b) in &out {
            self.stats.record_read(b.len());
        }
        Ok(out)
    }

    fn keys(&self, table: &str) -> Result<Vec<u64>> {
        let mut keys = self.base().keys(table)?;
        let (live, dead) = self.wal.overlay_keys(table);
        if !live.is_empty() || !dead.is_empty() {
            keys.extend(live);
            keys.sort_unstable();
            keys.dedup();
            if !dead.is_empty() {
                let dead: std::collections::HashSet<u64> = dead.into_iter().collect();
                keys.retain(|k| !dead.contains(k));
            }
        }
        Ok(keys)
    }

    fn tables(&self) -> Result<Vec<String>> {
        let mut t = self.base().tables()?;
        t.extend(self.wal.overlay_tables());
        t.sort();
        t.dedup();
        Ok(t)
    }

    fn stats(&self) -> &IoStats {
        &self.stats
    }

    /// Everything appended through this engine is already durable in the
    /// log when the call returns; `sync` additionally syncs both devices.
    fn sync(&self) -> Result<()> {
        self.wal.log_engine().sync()?;
        self.base().sync()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;
    use crate::wal::WalConfig;

    fn wal_engine() -> (WalEngine, Engine, Engine) {
        let log: Engine = Arc::new(MemStore::new());
        let dest: Engine = Arc::new(MemStore::new());
        let cfg = WalConfig { background_flush: false, ..WalConfig::default() };
        let wal = Wal::open("t", Arc::clone(&log), Arc::clone(&dest), cfg).unwrap();
        (WalEngine::new(wal), log, dest)
    }

    #[test]
    fn conformance() {
        let (e, _log, _dest) = wal_engine();
        crate::storage::tests::conformance(&e);
    }

    #[test]
    fn read_your_writes_before_flush() {
        let (e, _log, dest) = wal_engine();
        e.put("tbl", 3, b"three").unwrap();
        assert_eq!(**e.get("tbl", 3).unwrap().unwrap(), *b"three");
        // The destination has not seen the write.
        assert_eq!(dest.get("tbl", 3).unwrap(), None);
        // ... and still answers identically after the flush.
        e.wal().flush_now().unwrap();
        assert_eq!(**e.get("tbl", 3).unwrap().unwrap(), *b"three");
        assert_eq!(**dest.get("tbl", 3).unwrap().unwrap(), *b"three");
    }

    #[test]
    fn overlay_masks_base_after_delete() {
        let (e, _log, dest) = wal_engine();
        dest.put("tbl", 9, b"base").unwrap();
        assert!(e.get("tbl", 9).unwrap().is_some());
        e.delete("tbl", 9).unwrap();
        assert!(e.get("tbl", 9).unwrap().is_none(), "logged delete must mask base");
        assert!(!e.keys("tbl").unwrap().contains(&9));
        e.wal().flush_now().unwrap();
        assert!(dest.get("tbl", 9).unwrap().is_none());
    }

    #[test]
    fn get_run_merges_overlay_over_base() {
        let (e, _log, dest) = wal_engine();
        // Base holds keys 0, 2, 4; the log holds 1 (new), 2 (newer), and
        // a delete of 4.
        dest.put("tbl", 0, b"b0").unwrap();
        dest.put("tbl", 2, b"b2").unwrap();
        dest.put("tbl", 4, b"b4").unwrap();
        e.put("tbl", 1, b"w1").unwrap();
        e.put("tbl", 2, b"w2").unwrap();
        e.delete("tbl", 4).unwrap();
        let run = e.get_run("tbl", 0, 8).unwrap();
        let keys: Vec<u64> = run.iter().map(|(k, _)| *k).collect();
        assert_eq!(keys, vec![0, 1, 2]);
        assert_eq!(**run[1].1, *b"w1");
        assert_eq!(**run[2].1, *b"w2", "overlay must win over stale base");
    }

    #[test]
    fn get_batch_mixes_overlay_and_base() {
        let (e, _log, dest) = wal_engine();
        dest.put("tbl", 10, b"base10").unwrap();
        e.put("tbl", 11, b"log11").unwrap();
        let got = e.get_batch("tbl", &[10, 11, 12]).unwrap();
        assert_eq!(got[0].as_deref().map(|v| &v[..]), Some(b"base10".as_ref()));
        assert_eq!(got[1].as_deref().map(|v| &v[..]), Some(b"log11".as_ref()));
        assert_eq!(got[2], None);
    }

    #[test]
    fn keys_and_tables_are_merged_views() {
        let (e, _log, dest) = wal_engine();
        dest.put("a", 1, b"x").unwrap();
        e.put("b", 2, b"y").unwrap();
        assert_eq!(e.keys("a").unwrap(), vec![1]);
        assert_eq!(e.keys("b").unwrap(), vec![2]);
        let tables = e.tables().unwrap();
        assert!(tables.contains(&"a".to_string()));
        assert!(tables.contains(&"b".to_string()));
    }
}
