//! PJRT runtime: loads the AOT-compiled HLO text artifacts and executes
//! them from the Rust request path. Python is never involved here — this
//! is the Layer-3 side of the AOT bridge.
//!
//! One [`Runtime`] owns one PJRT CPU client and a registry of compiled
//! executables (one per model variant, compiled once at load). Execution
//! is thread-safe; worker threads of the vision pipeline call
//! [`Runtime::run3d`] concurrently.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::array::DenseVolume;
use crate::{Error, Result};

/// Static shape registry for the shipped artifacts (must match
/// python/compile/model.py; checked against artifacts/manifest.txt at
/// load).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GraphSpec {
    pub name: &'static str,
    pub input: [usize; 3],
    pub output: [usize; 3],
}

/// The three shipped graphs (dims are Rust-side `[X, Y, Z]`; the HLO
/// entry shapes are the same buffers labeled `[Z, Y, X]` row-major —
/// identical memory order, zero copies across the bridge).
pub const GRAPHS: [GraphSpec; 3] = [
    GraphSpec { name: "synapse_detector", input: [144, 144, 24], output: [128, 128, 16] },
    GraphSpec { name: "color_correct", input: [256, 256, 32], output: [256, 256, 32] },
    GraphSpec { name: "downsample2x", input: [128, 128, 16], output: [64, 64, 16] },
];

/// Halo the synapse detector expects around its core block `[X, Y, Z]`.
/// Must exceed the composed filter radius (see python/compile/model.py).
pub const DETECTOR_HALO: [u64; 3] = [8, 8, 4];

struct Loaded {
    exe: xla::PjRtLoadedExecutable,
    spec: GraphSpec,
}

/// PJRT CPU client + compiled executables.
pub struct Runtime {
    _client: xla::PjRtClient,
    // The xla crate's executables are not Sync; serialize dispatch. CPU
    // PJRT parallelizes inside a computation, and pipeline-level
    // parallelism comes from running many blocks through the queue.
    exes: Mutex<HashMap<String, Loaded>>,
}

// Safety: the PJRT CPU client is internally synchronized; we additionally
// serialize all calls through the mutex above.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Create a runtime and load every artifact found in `dir`
    /// (`<name>.hlo.txt` files produced by `make artifacts`).
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let client = xla::PjRtClient::cpu()?;
        let mut exes = HashMap::new();
        for spec in GRAPHS {
            let path = dir.join(format!("{}.hlo.txt", spec.name));
            if !path.exists() {
                continue; // partial artifact sets are fine for tests
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Other("bad path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            exes.insert(spec.name.to_string(), Loaded { exe, spec });
        }
        if exes.is_empty() {
            return Err(Error::NotFound(format!(
                "no artifacts in {dir:?} — run `make artifacts` first"
            )));
        }
        Ok(Runtime { _client: client, exes: Mutex::new(exes) })
    }

    /// Names of loaded graphs.
    pub fn graphs(&self) -> Vec<String> {
        let mut g: Vec<String> = self.exes.lock().unwrap().keys().cloned().collect();
        g.sort();
        g
    }

    /// Spec for a loaded graph.
    pub fn spec(&self, name: &str) -> Result<GraphSpec> {
        self.exes
            .lock()
            .unwrap()
            .get(name)
            .map(|l| l.spec)
            .ok_or_else(|| Error::NotFound(format!("graph '{name}'")))
    }

    /// Execute a graph on one f32 volume, returning the f32 output volume.
    ///
    /// The volume's x-fastest layout maps to the HLO's row-major
    /// `f32[X,Y,Z]` with dimensions reversed; rather than transpose, we
    /// declare the literal with reversed dims on both sides, which is a
    /// pure relabeling (the memory order is identical).
    pub fn run3d(&self, name: &str, input: &DenseVolume<f32>) -> Result<DenseVolume<f32>> {
        let guard = self.exes.lock().unwrap();
        let loaded = guard
            .get(name)
            .ok_or_else(|| Error::NotFound(format!("graph '{name}'")))?;
        let spec = loaded.spec;
        let dims = input.dims();
        if [dims[0] as usize, dims[1] as usize, dims[2] as usize] != spec.input {
            return Err(Error::BadRequest(format!(
                "graph '{name}' expects input {:?}, got {:?}",
                spec.input, dims
            )));
        }
        // DenseVolume is x-fastest; XLA literals are row-major (last dim
        // fastest). Present the buffer as [Z, Y, X].
        let lit = xla::Literal::vec1(input.as_slice()).reshape(&[
            dims[2] as i64,
            dims[1] as i64,
            dims[0] as i64,
        ])?;
        let result = loaded.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        drop(guard);
        DenseVolume::from_vec(
            [spec.output[0] as u64, spec.output[1] as u64, spec.output[2] as u64],
            values,
        )
    }
}

/// Default artifact directory: `$OCPD_ARTIFACTS` or `./artifacts`.
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var("OCPD_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/pipeline_e2e.rs
    // (they require `make artifacts`). Here: spec registry sanity.

    #[test]
    fn graph_specs_consistent() {
        for g in GRAPHS {
            assert!(g.input.iter().all(|&d| d > 0));
            assert!(g.output.iter().all(|&d| d > 0));
        }
        // Detector: input = output + 2 * halo.
        let det = GRAPHS[0];
        for a in 0..3 {
            assert_eq!(det.input[a], det.output[a] + 2 * DETECTOR_HALO[a] as usize);
        }
        // Downsample halves XY only.
        let ds = GRAPHS[2];
        assert_eq!(ds.output, [ds.input[0] / 2, ds.input[1] / 2, ds.input[2]]);
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Runtime::load_dir("/nonexistent-ocpd-artifacts").is_err());
    }
}
