//! The cutout service: efficient extraction (and writing) of arbitrary
//! sub-volumes — the query that "guides the design of the OCP Data
//! System" (§4.2).
//!
//! A cutout request specifies a resolution and a range in each dimension.
//! The read path is a **parallel fan-out engine** modeled on the paper's
//! "parallel disk arrays" claim (§4.1 — a single request spreads across
//! spindles and nodes):
//!
//! 1. cover the request box with cuboids and sort their Morton codes,
//! 2. coalesce the codes into maximal contiguous runs and split each run
//!    at shard boundaries ([`crate::shard::ShardMap`], via the engine's
//!    [`shard_map`]) so no batch straddles two nodes,
//! 3. chop the shard-aligned runs into roughly
//!    `workers × batches_per_worker` batches ([`ReadConfig`]),
//! 4. scatter the batches across a scoped worker pool
//!    (`std::thread::scope`); each worker streams its runs through the
//!    store ([`crate::chunkstore`], cache-first) and assembles its
//!    cuboids **directly into disjoint regions of the output volume**,
//!    so the merge needs no lock,
//! 5. the store consults the sharded LRU cuboid cache
//!    ([`crate::chunkstore::CuboidCache`]) before touching the engine.
//!
//! The **write path is the same engine run in reverse** (the paper's
//! write claim: annotation workloads are "directed to solid-state
//! storage" and sustained at ingest bandwidth, §4.1):
//!
//! 1. cover the request box with cuboids and classify each as *fully*
//!    or *partially* covered;
//! 2. under an overwrite merge, fully covered cuboids **elide** their
//!    existing-cuboid read — the stored value cannot influence the
//!    result, so cuboid-aligned bulk ingest performs zero reads;
//! 3. partially covered cuboids batch their pre-reads through
//!    [`CuboidStore::read_cuboids`] (Morton-coalesced runs + cache)
//!    instead of one point read per cuboid;
//! 4. a [`WriteConfig`] plans shard-aligned batches and scatters
//!    merge + commit across the scoped pool: workers own disjoint
//!    cuboids (lock-free merge), and each worker's
//!    `put_batch`/`delete_batch` lands wholly on one node, so a single
//!    write fans out across the cluster like a read does.
//!
//! Parallel writes are byte-identical to sequential ones for every
//! merge discipline (property-tested); `BENCH_write.json` records the
//! writer scaling and elision effect.
//!
//! The in-memory assembly copy is the system's memory hot path (§5:
//! unaligned cutouts drop throughput from 173 to 61 MB/s purely from
//! in-memory reorganization). [`CutoutService::classify`] reports whether
//! a request is cuboid-aligned, which the benches use to reproduce
//! Figure 10's three curves; `BENCH_cutout.json` records the fan-out and
//! cache speedups.
//!
//! [`shard_map`]: crate::storage::StorageEngine::shard_map

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use crate::array::{DenseVolume, Plane, VoxelScalar};
use crate::chunkstore::CuboidStore;
use crate::core::{Box3, Vec3};
use crate::metrics::{Counter, Histogram};
use crate::morton;
use crate::obs::account::Ledger;
use crate::qos::{GateGuard, Pool, QosEnforcer};
use crate::util::pool::scoped_map;
use crate::{Error, Result};

/// Alignment class of a cutout request (Figure 10's configurations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Alignment {
    /// Box boundaries coincide with cuboid boundaries: assembly is pure
    /// whole-cuboid placement.
    Aligned,
    /// Box cuts through cuboids: every boundary cuboid pays a partial
    /// copy with cache-unfriendly strides.
    Unaligned,
}

/// Tuning knobs for the parallel read engine.
#[derive(Clone, Copy, Debug)]
pub struct ReadConfig {
    /// Fan-out width: scoped worker threads per read (1 = sequential).
    pub workers: usize,
    /// Minimum cuboid count before a read fans out; smaller requests run
    /// on the caller's thread (thread setup would dominate).
    pub parallel_threshold: usize,
    /// Batch granularity: runs are chopped so each worker sees about
    /// this many batches, which load-balances skewed runs.
    pub batches_per_worker: usize,
}

impl Default for ReadConfig {
    fn default() -> Self {
        let workers =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
        ReadConfig { workers, parallel_threshold: 4, batches_per_worker: 2 }
    }
}

impl ReadConfig {
    /// A sequential configuration (benches' baseline; also useful under
    /// an outer parallelism layer).
    pub fn sequential() -> Self {
        ReadConfig { workers: 1, ..ReadConfig::default() }
    }

    /// Fan-out width `n` with defaults elsewhere.
    pub fn with_workers(n: usize) -> Self {
        ReadConfig { workers: n.max(1), ..ReadConfig::default() }
    }
}

/// Tuning knobs for the parallel write engine (the mirror of
/// [`ReadConfig`]): how wide a single `write`/`write_with` scatters its
/// merge + commit work.
#[derive(Clone, Copy, Debug)]
pub struct WriteConfig {
    /// Fan-out width: scoped worker threads per write (1 = sequential).
    pub workers: usize,
    /// Minimum covered-cuboid count before a write fans out.
    pub parallel_threshold: usize,
    /// Batch granularity: shard-aligned runs are chopped so each worker
    /// sees about this many batches.
    pub batches_per_worker: usize,
}

impl Default for WriteConfig {
    fn default() -> Self {
        let workers =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
        WriteConfig { workers, parallel_threshold: 4, batches_per_worker: 2 }
    }
}

impl WriteConfig {
    /// A sequential configuration (benches' baseline).
    pub fn sequential() -> Self {
        WriteConfig { workers: 1, ..WriteConfig::default() }
    }

    /// Fan-out width `n` with defaults elsewhere.
    pub fn with_workers(n: usize) -> Self {
        WriteConfig { workers: n.max(1), ..WriteConfig::default() }
    }
}

/// Read-engine counters: how often reads fan out and how wide.
#[derive(Debug, Default)]
pub struct ReadMetrics {
    /// Reads served on the caller's thread.
    pub sequential_reads: Counter,
    /// Reads scattered across the worker pool.
    pub parallel_reads: Counter,
    /// Batches per parallel read (the fan-out width distribution).
    pub fanout_width: Histogram,
}

/// Write-engine counters: fan-out, RMW elision, and merge cost.
#[derive(Debug, Default)]
pub struct WriteMetrics {
    /// Writes merged and committed on the caller's thread.
    pub sequential_writes: Counter,
    /// Writes scattered across the worker pool.
    pub parallel_writes: Counter,
    /// Batches per parallel write (the fan-out width distribution).
    pub fanout_width: Histogram,
    /// Cuboids whose existing-contents read was **elided**: fully covered
    /// by the request box under an overwrite merge, so the stored value
    /// cannot influence the result. Bulk ingest never reads.
    pub elided_reads: Counter,
    /// Cuboids that paid a read-modify-write pre-read (partial coverage,
    /// or a merge that depends on the existing voxels).
    pub rmw_reads: Counter,
    /// Per-batch merge latency (pre-read excluded; the in-memory
    /// voxel-merge cost the scatter parallelizes).
    pub merge_latency: Histogram,
}

/// Point-in-time view of one service's write engine — configuration plus
/// counters (the `GET /write/status/` surface).
#[derive(Clone, Copy, Debug)]
pub struct WriteStatus {
    pub workers: usize,
    pub parallel_threshold: usize,
    pub sequential_writes: u64,
    pub parallel_writes: u64,
    pub elided_reads: u64,
    pub rmw_reads: u64,
    pub merge_mean_us: f64,
    pub merge_p95_us: u64,
}

/// One covered cuboid in a write plan: where it sits in the grid and
/// how much of it the request box covers.
struct WriteItem {
    code: u64,
    /// The cuboid's global box (may extend past the dataset bounds at
    /// the volume edge).
    cub_box: Box3,
    /// `cub_box ∩ bx` — the region the request actually writes.
    isect: Box3,
    /// Fully covered under an overwrite merge: the stored value cannot
    /// influence the result, so the pre-read is elided.
    full: bool,
}

/// Unsynchronized writer into the output volume. Workers copy their
/// cuboids' voxels into *disjoint* destination boxes — the batch plan
/// partitions the code set, and distinct cuboids intersect the request
/// box in disjoint regions — so the merge is lock-free by construction.
struct RawOut<T> {
    ptr: *mut T,
    dims: Vec3,
}

// Safety: every write through `ptr` targets a region derived from a
// cuboid owned by exactly one worker (see `plan_batches`), and the
// allocation outlives the thread scope.
unsafe impl<T: VoxelScalar> Send for RawOut<T> {}
unsafe impl<T: VoxelScalar> Sync for RawOut<T> {}

impl<T: VoxelScalar> RawOut<T> {
    /// Copy `src_box` of `src` to `dst_lo`, x-run at a time — the same
    /// kernel as [`DenseVolume::copy_box_from`], against the raw output.
    ///
    /// Safety: caller guarantees the destination region is disjoint from
    /// every other concurrent copy and within `dims`.
    unsafe fn copy_box_from(&self, src: &DenseVolume<T>, src_box: Box3, dst_lo: Vec3) {
        let e = src_box.extent();
        let run = e[0] as usize;
        let src_data = src.as_slice();
        for dz in 0..e[2] {
            for dy in 0..e[1] {
                let si = src.index([src_box.lo[0], src_box.lo[1] + dy, src_box.lo[2] + dz]);
                let ti = (dst_lo[0]
                    + self.dims[0]
                        * ((dst_lo[1] + dy) + self.dims[1] * (dst_lo[2] + dz)))
                    as usize;
                std::ptr::copy_nonoverlapping(src_data.as_ptr().add(si), self.ptr.add(ti), run);
            }
        }
    }
}

/// Cutout reader/writer over one project's cuboid store.
pub struct CutoutService {
    store: Arc<CuboidStore>,
    cfg: ReadConfig,
    /// Write-engine configuration. Behind a lock (unlike the read
    /// config) because the `/write/workers/{n}/` route retunes live
    /// services.
    wcfg: RwLock<WriteConfig>,
    /// Read-engine observability (fan-out widths, parallel/sequential
    /// split); cache counters live on the store's [`CuboidCache`].
    ///
    /// [`CuboidCache`]: crate::chunkstore::CuboidCache
    pub metrics: ReadMetrics,
    /// Write-engine observability (fan-out, elided vs RMW pre-reads,
    /// merge latency).
    pub write_metrics: WriteMetrics,
    /// The project's tenant ledger (DESIGN.md §11): the read and write
    /// engines charge their workers' busy time here when the cluster
    /// attaches one. Set once; reads are lock-free.
    ledger: OnceLock<Arc<Ledger>>,
    /// The cluster's QoS enforcer (DESIGN.md §12): read/write batches
    /// acquire fair-gate slots and honor request deadlines when one is
    /// attached. Set once; reads are lock-free.
    qos: OnceLock<Arc<QosEnforcer>>,
}

impl CutoutService {
    pub fn new(store: Arc<CuboidStore>) -> Self {
        CutoutService {
            store,
            cfg: ReadConfig::default(),
            wcfg: RwLock::new(WriteConfig::default()),
            metrics: ReadMetrics::default(),
            write_metrics: WriteMetrics::default(),
            ledger: OnceLock::new(),
            qos: OnceLock::new(),
        }
    }

    /// Attach the project's resource ledger. Idempotent: the first
    /// attach wins.
    pub fn set_ledger(&self, ledger: Arc<Ledger>) {
        let _ = self.ledger.set(ledger);
    }

    /// The attached ledger, if any.
    pub fn ledger(&self) -> Option<&Arc<Ledger>> {
        self.ledger.get()
    }

    /// Attach the cluster's QoS enforcer. Idempotent: the first attach
    /// wins (a migration rebind re-attaches the same enforcer).
    pub fn set_qos(&self, qos: Arc<QosEnforcer>) {
        let _ = self.qos.set(qos);
    }

    /// Acquire a fair-gate slot for one batch of work in `pool`.
    /// `None` when no enforcer is attached (library use, unit tests);
    /// a disabled enforcer returns a free guard.
    fn qos_enter(&self, pool: Pool) -> Option<GateGuard<'_>> {
        self.qos.get().map(|q| q.enter(pool))
    }

    /// Override the read-engine configuration.
    pub fn with_read_config(mut self, cfg: ReadConfig) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn read_config(&self) -> ReadConfig {
        self.cfg
    }

    /// Override the write-engine configuration (builder form).
    pub fn with_write_config(self, cfg: WriteConfig) -> Self {
        *self.wcfg.write().unwrap() = cfg;
        self
    }

    pub fn write_config(&self) -> WriteConfig {
        *self.wcfg.read().unwrap()
    }

    /// Retune the write engine on a live service (the workers knob).
    pub fn set_write_config(&self, cfg: WriteConfig) {
        *self.wcfg.write().unwrap() = cfg;
    }

    /// Snapshot of the write engine's configuration and counters.
    pub fn write_status(&self) -> WriteStatus {
        let cfg = self.write_config();
        let m = &self.write_metrics;
        WriteStatus {
            workers: cfg.workers,
            parallel_threshold: cfg.parallel_threshold,
            sequential_writes: m.sequential_writes.get(),
            parallel_writes: m.parallel_writes.get(),
            elided_reads: m.elided_reads.get(),
            rmw_reads: m.rmw_reads.get(),
            merge_mean_us: m.merge_latency.mean_us(),
            merge_p95_us: m.merge_latency.percentile_us(95.0),
        }
    }

    pub fn store(&self) -> &Arc<CuboidStore> {
        &self.store
    }

    /// Morton code for a cuboid-grid coordinate, folding in the timestep
    /// for 4-d (time-series) datasets (§3.1).
    fn code(&self, c: Vec3, t: u64) -> u64 {
        if self.store.dataset.timesteps > 1 {
            morton::encode4(c[0], c[1], c[2], t)
        } else {
            morton::encode3(c[0], c[1], c[2])
        }
    }

    /// Classify a request against the cuboid grid.
    pub fn classify(&self, res: u32, bx: &Box3) -> Result<Alignment> {
        let shape = self.store.cuboid_shape(res)?;
        Ok(if bx.is_aligned(shape) { Alignment::Aligned } else { Alignment::Unaligned })
    }

    /// Partition `bx` into z-slabs for a streaming read: consecutive
    /// boxes that tile `bx` in z-order, each targeting at most
    /// `max_voxels` voxels. Because the dense output layout is
    /// x-fastest, the concatenated slab payloads are byte-identical to
    /// one whole-box read — the web tier streams them as chunks under a
    /// single volume header.
    ///
    /// When the budget allows at least one whole cuboid z-layer group,
    /// slabs are rounded to cuboid-aligned z-groups so no cuboid is
    /// fetched by two slabs. For very wide XY extents — where even one
    /// cuboid layer group busts the budget — slabs fall back to thinner
    /// z-cuts (floor: a single z-layer of the request, the thinnest
    /// contiguous unit of the output), trading bounded cuboid re-reads
    /// (absorbed by the cuboid cache when it fits) for a hard memory
    /// bound.
    ///
    /// Validates the request up front (same checks as
    /// [`read`](Self::read)) so a caller can fail before committing to
    /// a streamed response.
    pub fn slab_boxes(&self, res: u32, bx: Box3, max_voxels: usize) -> Result<Vec<Box3>> {
        self.store.dataset.check_box(res, &bx)?;
        let cz = self.store.cuboid_shape(res)?[2].max(1);
        let e = bx.extent();
        let plane_voxels = (e[0] * e[1]).max(1);
        let budget_layers = (max_voxels as u64 / plane_voxels).max(1);
        // Whole cuboid z-groups when they fit the budget; thinner
        // (sub-cuboid) cuts when a single group would not.
        let layers =
            if budget_layers >= cz { (budget_layers / cz) * cz } else { budget_layers };
        let mut out = Vec::new();
        let mut z = bx.lo[2];
        while z < bx.hi[2] {
            // Cut at global grid multiples of `layers` so every slab
            // boundary is a cuboid boundary.
            let next = ((z / layers + 1) * layers).min(bx.hi[2]);
            out.push(Box3::new([bx.lo[0], bx.lo[1], z], [bx.hi[0], bx.hi[1], next]));
            z = next;
        }
        Ok(out)
    }

    /// Read the sub-volume `bx` at `(res, channel, timestep)`, fanning
    /// out across the worker pool per [`ReadConfig`].
    pub fn read<T: VoxelScalar>(
        &self,
        res: u32,
        channel: u16,
        t: u64,
        bx: Box3,
    ) -> Result<DenseVolume<T>> {
        self.read_with_workers(res, channel, t, bx, self.cfg.workers)
    }

    /// `read` with an explicit fan-out width (1 = sequential). Used by
    /// [`CutoutService::read_timeseries`], which spends its parallelism
    /// across timesteps instead, and by the parity tests/benches.
    pub fn read_with_workers<T: VoxelScalar>(
        &self,
        res: u32,
        channel: u16,
        t: u64,
        bx: Box3,
        workers: usize,
    ) -> Result<DenseVolume<T>> {
        self.read_impl(res, channel, t, bx, workers, true)
    }

    fn read_impl<T: VoxelScalar>(
        &self,
        res: u32,
        channel: u16,
        t: u64,
        bx: Box3,
        workers: usize,
        record: bool,
    ) -> Result<DenseVolume<T>> {
        self.store.dataset.check_box(res, &bx)?;
        self.store.dataset.check_timestep(t)?;
        self.store.dataset.check_channel(channel)?;
        let mut sp = crate::obs::trace::span("cutout", "read");
        sp.tag("res", res.to_string());
        sp.tag("extent", format!("{:?}", bx.extent()));
        let cshape = self.store.cuboid_shape(res)?;
        let cover = bx.cuboid_cover(cshape);

        // Sorted cuboid codes covering the request.
        let mut codes: Vec<u64> = Vec::with_capacity(cover.volume() as usize);
        for cz in cover.lo[2]..cover.hi[2] {
            for cy in cover.lo[1]..cover.hi[1] {
                for cx in cover.lo[0]..cover.hi[0] {
                    codes.push(self.code([cx, cy, cz], t));
                }
            }
        }
        codes.sort_unstable();
        sp.tag("cuboids", codes.len().to_string());

        let mut out = DenseVolume::<T>::zeros(bx.extent());
        if codes.is_empty() {
            return Ok(out);
        }

        // Guaranteed-sequential reads skip batch planning entirely (a
        // per-timestep call from `read_timeseries` would otherwise plan
        // and discard on every step).
        let batches = if workers <= 1 || codes.len() < self.cfg.parallel_threshold {
            Vec::new()
        } else {
            self.plan_batches(&codes, workers)
        };
        if batches.len() <= 1 {
            // Sequential path: one streaming pass, assemble in place.
            if record {
                self.metrics.sequential_reads.inc();
            }
            crate::qos::ctx::check_deadline()?;
            let _slot = self.qos_enter(Pool::Read);
            let t0 = std::time::Instant::now();
            let cuboids = self.store.read_cuboids::<T>(res, channel, &codes)?;
            for (code, cub) in codes.iter().zip(cuboids) {
                let Some(cub) = cub else { continue }; // lazy: absent = zeros
                let Some((src, dst)) = self.placement(*code, cshape, &bx) else { continue };
                out.copy_box_from(&cub, src, dst);
            }
            if let Some(l) = self.ledger.get() {
                l.add_read_worker_us(t0.elapsed().as_micros() as u64);
            }
            return Ok(out);
        }

        // Parallel path: scatter batches over scoped workers, each
        // assembling into its own disjoint region of `out`.
        if record {
            self.metrics.parallel_reads.inc();
            self.metrics.fanout_width.record_value(batches.len() as u64);
        }
        let raw = RawOut::<T> { ptr: out.as_mut_slice().as_mut_ptr(), dims: bx.extent() };
        // Summed per-batch busy time — the tenant's worker-seconds bill
        // is what the pool actually spent, not the request's wall time.
        let busy_us = AtomicU64::new(0);
        let results = scoped_map(batches.len(), workers, |b| -> Result<()> {
            let t0 = std::time::Instant::now();
            let r = (|| -> Result<()> {
                // Batch boundary: an expired request stops here rather
                // than finishing work nobody waits for, and the fair
                // gate interleaves this batch with other tenants'.
                crate::qos::ctx::check_deadline()?;
                let _slot = self.qos_enter(Pool::Read);
                let (lo, hi) = batches[b];
                let chunk = &codes[lo..hi];
                let mut bsp = crate::obs::trace::span("cutout", format!("batch {b}"));
                bsp.tag("cuboids", chunk.len().to_string());
                let cuboids = self.store.read_cuboids::<T>(res, channel, chunk)?;
                for (code, cub) in chunk.iter().zip(cuboids) {
                    let Some(cub) = cub else { continue };
                    let Some((src, dst)) = self.placement(*code, cshape, &bx) else { continue };
                    // Safety: batches partition the code set, and distinct
                    // cuboids map to disjoint regions of the output.
                    unsafe { raw.copy_box_from(&cub, src, dst) };
                }
                Ok(())
            })();
            busy_us.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
            r
        });
        for r in results {
            r?;
        }
        if let Some(l) = self.ledger.get() {
            l.add_read_worker_us(busy_us.load(Ordering::Relaxed));
        }
        Ok(out)
    }

    /// The batch plan: Morton-contiguous, shard-aligned index ranges
    /// into the sorted code list.
    ///
    /// 1. coalesce codes into maximal contiguous runs;
    /// 2. split each run at shard boundaries (when the engine is a
    ///    [`crate::cluster::ShardedEngine`]) so a batch never straddles
    ///    nodes;
    /// 3. chop runs to at most `ceil(n / (workers × batches_per_worker))`
    ///    codes so the pool load-balances skewed runs.
    fn plan_batches(&self, codes: &[u64], workers: usize) -> Vec<(usize, usize)> {
        self.plan_batches_with(codes, workers, self.cfg.batches_per_worker)
    }

    /// [`plan_batches`](Self::plan_batches) with an explicit batch
    /// granularity — shared by the read and write engines, which carry
    /// their own `batches_per_worker` knobs.
    fn plan_batches_with(
        &self,
        codes: &[u64],
        workers: usize,
        batches_per_worker: usize,
    ) -> Vec<(usize, usize)> {
        let map = self.store.engine().shard_map();
        let mut bounds: Vec<(usize, usize)> = Vec::new();
        let mut idx = 0usize;
        for run in morton::coalesce_runs(codes) {
            match map.as_deref() {
                Some(m) => {
                    for (_node, lo, len) in m.route_run(run.start, run.len) {
                        let off = (lo - run.start) as usize;
                        bounds.push((idx + off, idx + off + len as usize));
                    }
                }
                None => bounds.push((idx, idx + run.len as usize)),
            }
            idx += run.len as usize;
        }
        let target = codes
            .len()
            .div_ceil(workers.max(1) * batches_per_worker.max(1))
            .max(1);
        let mut out = Vec::new();
        for (lo, hi) in bounds {
            let mut cur = lo;
            while cur < hi {
                let end = (cur + target).min(hi);
                out.push((cur, end));
                cur = end;
            }
        }
        out
    }

    /// Where `code`'s cuboid lands in the request box: the source box
    /// within the cuboid and the destination offset within the output.
    /// `None` when the cuboid does not intersect the box.
    fn placement(&self, code: u64, cshape: Vec3, bx: &Box3) -> Option<(Box3, Vec3)> {
        let (cx, cy, cz) = self.decode(code);
        let cub_box = Box3::at([cx * cshape[0], cy * cshape[1], cz * cshape[2]], cshape);
        let isect = cub_box.intersect(bx);
        if isect.is_empty() {
            return None;
        }
        let src = Box3::new(
            [
                isect.lo[0] - cub_box.lo[0],
                isect.lo[1] - cub_box.lo[1],
                isect.lo[2] - cub_box.lo[2],
            ],
            [
                isect.hi[0] - cub_box.lo[0],
                isect.hi[1] - cub_box.lo[1],
                isect.hi[2] - cub_box.lo[2],
            ],
        );
        let dst = [isect.lo[0] - bx.lo[0], isect.lo[1] - bx.lo[1], isect.lo[2] - bx.lo[2]];
        Some((src, dst))
    }

    fn decode(&self, code: u64) -> (u64, u64, u64) {
        if self.store.dataset.timesteps > 1 {
            let (x, y, z, _t) = morton::decode4(code);
            (x, y, z)
        } else {
            morton::decode3(code)
        }
    }

    /// Write `vol` into the volume at `bx` under a read-modify-write
    /// merge. `merge` decides the value per voxel given
    /// `(existing, incoming)` — the write disciplines for annotations.
    /// Because `merge` may depend on the existing voxels, every covered
    /// cuboid pays a pre-read, batched through
    /// [`CuboidStore::read_cuboids`] (Morton-coalesced runs + cache);
    /// use [`write`](Self::write) for overwrite semantics, which elides
    /// the reads of fully covered cuboids. Fans out per [`WriteConfig`].
    pub fn write_with<T: VoxelScalar>(
        &self,
        res: u32,
        channel: u16,
        t: u64,
        bx: Box3,
        vol: &DenseVolume<T>,
        merge: impl Fn(T, T) -> T + Sync,
    ) -> Result<()> {
        self.write_impl(res, channel, t, bx, vol, &merge, false, None)
    }

    /// Plain overwrite write (image ingest path). Cuboids fully covered
    /// by `bx` skip their existing-cuboid read entirely — cuboid-aligned
    /// bulk ingest never reads at all. Fans out per [`WriteConfig`].
    pub fn write<T: VoxelScalar>(
        &self,
        res: u32,
        channel: u16,
        t: u64,
        bx: Box3,
        vol: &DenseVolume<T>,
    ) -> Result<()> {
        self.write_impl(res, channel, t, bx, vol, &|_, new| new, true, None)
    }

    /// [`write`](Self::write) with an explicit fan-out width
    /// (1 = sequential) — parity tests and benches.
    pub fn write_with_workers<T: VoxelScalar>(
        &self,
        res: u32,
        channel: u16,
        t: u64,
        bx: Box3,
        vol: &DenseVolume<T>,
        workers: usize,
    ) -> Result<()> {
        self.write_impl(res, channel, t, bx, vol, &|_, new| new, true, Some(workers))
    }

    /// [`write_with`](Self::write_with) with an explicit fan-out width
    /// (1 = sequential) — parity tests and benches.
    #[allow(clippy::too_many_arguments)]
    pub fn write_rmw_with_workers<T: VoxelScalar>(
        &self,
        res: u32,
        channel: u16,
        t: u64,
        bx: Box3,
        vol: &DenseVolume<T>,
        merge: impl Fn(T, T) -> T + Sync,
        workers: usize,
    ) -> Result<()> {
        self.write_impl(res, channel, t, bx, vol, &merge, false, Some(workers))
    }

    /// The write engine. Mirrors `read_impl`:
    ///
    /// 1. cover `bx` with cuboids, sort by Morton code;
    /// 2. classify each cuboid: **full** (fully covered under an
    ///    overwrite merge — the stored value cannot influence the
    ///    result, so the pre-read is elided) vs **partial** (pays a
    ///    read-modify-write);
    /// 3. plan shard-aligned batches ([`WriteConfig`]) and scatter them
    ///    over a scoped worker pool — each worker owns disjoint cuboids,
    ///    so merging needs no locks;
    /// 4. each worker batch-reads its partial cuboids
    ///    ([`CuboidStore::read_cuboids`]: coalesced runs + cache),
    ///    merges in memory, and commits its own
    ///    [`CuboidStore::write_cuboids`] — shard alignment means each
    ///    commit's `put_batch`/`delete_batch` lands wholly on one node,
    ///    so concurrent workers scatter across the node set.
    #[allow(clippy::too_many_arguments)]
    fn write_impl<T: VoxelScalar>(
        &self,
        res: u32,
        channel: u16,
        t: u64,
        bx: Box3,
        vol: &DenseVolume<T>,
        merge: &(dyn Fn(T, T) -> T + Sync),
        overwrite: bool,
        workers: Option<usize>,
    ) -> Result<()> {
        if vol.dims() != bx.extent() {
            return Err(Error::BadRequest(format!(
                "volume dims {:?} != box extent {:?}",
                vol.dims(),
                bx.extent()
            )));
        }
        self.store.dataset.check_box(res, &bx)?;
        self.store.dataset.check_timestep(t)?;
        self.store.dataset.check_channel(channel)?;
        // One config snapshot per write: a concurrent retune can't split
        // a single request across two configurations.
        let wcfg = self.write_config();
        let workers = workers.unwrap_or(wcfg.workers);
        let cshape = self.store.cuboid_shape(res)?;
        let cover = bx.cuboid_cover(cshape);

        let mut items: Vec<WriteItem> = Vec::with_capacity(cover.volume() as usize);
        for cz in cover.lo[2]..cover.hi[2] {
            for cy in cover.lo[1]..cover.hi[1] {
                for cx in cover.lo[0]..cover.hi[0] {
                    let cub_box =
                        Box3::at([cx * cshape[0], cy * cshape[1], cz * cshape[2]], cshape);
                    let isect = cub_box.intersect(&bx);
                    if isect.is_empty() {
                        continue;
                    }
                    items.push(WriteItem {
                        code: self.code([cx, cy, cz], t),
                        cub_box,
                        isect,
                        full: overwrite && isect == cub_box,
                    });
                }
            }
        }
        items.sort_by_key(|i| i.code);
        if items.is_empty() {
            return Ok(());
        }
        let mut sp = crate::obs::trace::span("cutout", "write");
        sp.tag("res", res.to_string());
        sp.tag("extent", format!("{:?}", bx.extent()));
        sp.tag("cuboids", items.len().to_string());
        sp.tag("full", items.iter().filter(|i| i.full).count().to_string());

        let batches = if workers <= 1 || items.len() < wcfg.parallel_threshold {
            Vec::new()
        } else {
            let codes: Vec<u64> = items.iter().map(|i| i.code).collect();
            self.plan_batches_with(&codes, workers, wcfg.batches_per_worker)
        };
        if batches.len() <= 1 {
            self.write_metrics.sequential_writes.inc();
            crate::qos::ctx::check_deadline()?;
            let _slot = self.qos_enter(Pool::Write);
            let t0 = std::time::Instant::now();
            let r = self.merge_and_commit(res, channel, &items, &bx, vol, merge);
            if let Some(l) = self.ledger.get() {
                l.add_write_worker_us(t0.elapsed().as_micros() as u64);
            }
            return r;
        }

        self.write_metrics.parallel_writes.inc();
        self.write_metrics.fanout_width.record_value(batches.len() as u64);
        let busy_us = AtomicU64::new(0);
        let results = scoped_map(batches.len(), workers, |b| {
            let t0 = std::time::Instant::now();
            let r = (|| {
                // Batch boundary: deadline check + fair-gate slot, as
                // in the read engine.
                crate::qos::ctx::check_deadline()?;
                let _slot = self.qos_enter(Pool::Write);
                let (lo, hi) = batches[b];
                self.merge_and_commit(res, channel, &items[lo..hi], &bx, vol, merge)
            })();
            busy_us.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
            r
        });
        for r in results {
            r?;
        }
        if let Some(l) = self.ledger.get() {
            l.add_write_worker_us(busy_us.load(Ordering::Relaxed));
        }
        Ok(())
    }

    /// Merge one batch of covered cuboids and commit it. Pre-reads the
    /// partial cuboids in one batched, cache-aware fetch; full cuboids
    /// are carved straight out of the incoming volume (RMW elision).
    fn merge_and_commit<T: VoxelScalar>(
        &self,
        res: u32,
        channel: u16,
        items: &[WriteItem],
        bx: &Box3,
        vol: &DenseVolume<T>,
        merge: &(dyn Fn(T, T) -> T + Sync),
    ) -> Result<()> {
        let cshape = self.store.cuboid_shape(res)?;
        let need: Vec<u64> = items.iter().filter(|i| !i.full).map(|i| i.code).collect();
        let mut sp = crate::obs::trace::span("cutout", "merge_commit");
        sp.tag("cuboids", items.len().to_string());
        sp.tag("rmw", need.len().to_string());
        self.write_metrics.elided_reads.add((items.len() - need.len()) as u64);
        self.write_metrics.rmw_reads.add(need.len() as u64);
        let mut existing = if need.is_empty() {
            Vec::new()
        } else {
            self.store.read_cuboids::<T>(res, channel, &need)?
        };

        let t0 = std::time::Instant::now();
        let mut out: Vec<(u64, DenseVolume<T>)> = Vec::with_capacity(items.len());
        let mut j = 0usize; // cursor into `existing` (same order as `need`)
        for item in items {
            let (cub_box, isect) = (&item.cub_box, &item.isect);
            let cub = if item.full {
                // Elided: the merged cuboid is exactly the incoming box.
                vol.extract_box(Box3::new(
                    [
                        isect.lo[0] - bx.lo[0],
                        isect.lo[1] - bx.lo[1],
                        isect.lo[2] - bx.lo[2],
                    ],
                    [
                        isect.hi[0] - bx.lo[0],
                        isect.hi[1] - bx.lo[1],
                        isect.hi[2] - bx.lo[2],
                    ],
                ))
            } else {
                let mut cub = existing[j]
                    .take()
                    .unwrap_or_else(|| DenseVolume::zeros(cshape));
                j += 1;
                for z in isect.lo[2]..isect.hi[2] {
                    for y in isect.lo[1]..isect.hi[1] {
                        for x in isect.lo[0]..isect.hi[0] {
                            let local =
                                [x - cub_box.lo[0], y - cub_box.lo[1], z - cub_box.lo[2]];
                            let src = [x - bx.lo[0], y - bx.lo[1], z - bx.lo[2]];
                            let old = cub.get(local);
                            let new = merge(old, vol.get(src));
                            if new != old {
                                cub.set(local, new);
                            }
                        }
                    }
                }
                cub
            };
            out.push((item.code, cub));
        }
        self.write_metrics.merge_latency.record(t0.elapsed());

        let refs: Vec<(u64, &DenseVolume<T>)> = out.iter().map(|(c, v)| (*c, v)).collect();
        self.store.write_cuboids(res, channel, &refs)
    }

    /// Extract a 2-d plane through the volume — the projection service
    /// used by tiles and orthogonal visualization (§3.3). Reads the
    /// minimal one-voxel-thick box, so the "vast majority of the data"
    /// discarded by a naive implementation is never assembled.
    pub fn read_plane<T: VoxelScalar>(
        &self,
        res: u32,
        channel: u16,
        t: u64,
        plane: Plane,
        lo: [u64; 2],
        hi: [u64; 2],
    ) -> Result<(u64, u64, Vec<T>)> {
        let bx = match plane {
            Plane::Xy(z) => Box3::new([lo[0], lo[1], z], [hi[0], hi[1], z + 1]),
            Plane::Xz(y) => Box3::new([lo[0], y, lo[1]], [hi[0], y + 1, hi[1]]),
            Plane::Yz(x) => Box3::new([x, lo[0], lo[1]], [x + 1, hi[0], hi[1]]),
        };
        let vol = self.read::<T>(res, channel, t, bx)?;
        let local = match plane {
            Plane::Xy(_) => Plane::Xy(0),
            Plane::Xz(_) => Plane::Xz(0),
            Plane::Yz(_) => Plane::Yz(0),
        };
        Ok(vol.extract_plane(local))
    }

    /// Time series of a fixed box: one volume per timestep in
    /// `[t_lo, t_hi)` (§3.1: "queries that analyze the time history of a
    /// smaller region").
    ///
    /// Nesting-avoidance contract: with two or more timesteps and
    /// `workers > 1`, the request runs **one** `scoped_map` of width
    /// `min(nt, workers)` — one task per timestep — and every inner
    /// per-timestep read is forced to width 1, so thread scopes never
    /// nest and the total width never exceeds the configured budget.
    /// With a single timestep (or a sequential config) it degenerates to
    /// plain [`read`](Self::read) calls, which fan out *per read* as
    /// usual.
    pub fn read_timeseries<T: VoxelScalar>(
        &self,
        res: u32,
        channel: u16,
        t_lo: u64,
        t_hi: u64,
        bx: Box3,
    ) -> Result<Vec<DenseVolume<T>>> {
        let nt = t_hi.saturating_sub(t_lo) as usize;
        if nt >= 2 && self.cfg.workers > 1 {
            // One parallel read of width nt; the per-timestep inner reads
            // run on pool workers and are excluded from the counters.
            self.metrics.parallel_reads.inc();
            self.metrics.fanout_width.record_value(nt.min(self.cfg.workers) as u64);
            let results = scoped_map(nt, self.cfg.workers.min(nt), |i| {
                self.read_impl(res, channel, t_lo + i as u64, bx, 1, false)
            });
            return results.into_iter().collect();
        }
        (t_lo..t_hi).map(|t| self.read(res, channel, t, bx)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunkstore::CuboidStore;
    use crate::core::{DatasetBuilder, Project};
    use crate::storage::MemStore;
    use crate::util::prop::property;

    fn service(dims: Vec3, levels: u32) -> CutoutService {
        let ds = Arc::new(DatasetBuilder::new("t", dims).levels(levels).build());
        let pr = Arc::new(Project::annotation("ann", "t"));
        CutoutService::new(Arc::new(CuboidStore::new(ds, pr, Arc::new(MemStore::new()))))
    }

    /// Reference volume filled with a position hash so any misplacement is
    /// detected.
    fn hash_vol(bx: Box3) -> DenseVolume<u32> {
        let mut v = DenseVolume::zeros(bx.extent());
        for z in 0..v.dims()[2] {
            for y in 0..v.dims()[1] {
                for x in 0..v.dims()[0] {
                    let (gx, gy, gz) = (bx.lo[0] + x, bx.lo[1] + y, bx.lo[2] + z);
                    v.set([x, y, z], (gx * 1_000_003 + gy * 1_009 + gz + 1) as u32);
                }
            }
        }
        v
    }

    #[test]
    fn slab_boxes_tile_the_request_at_cuboid_boundaries() {
        let svc = service([256, 256, 64], 1);
        let cz = svc.store().cuboid_shape(0).unwrap()[2];
        let bx = Box3::new([3, 5, 1], [250, 251, 63]);
        // Budget of one cuboid z-layer's worth of voxels.
        let plane = (bx.extent()[0] * bx.extent()[1]) as usize;
        let slabs = svc.slab_boxes(0, bx, plane * cz as usize).unwrap();
        assert!(slabs.len() > 1, "{slabs:?}");
        // Slabs tile bx exactly, in z order, cutting only at cuboid
        // boundaries (except the request's own ends).
        assert_eq!(slabs.first().unwrap().lo, bx.lo);
        assert_eq!(slabs.last().unwrap().hi, bx.hi);
        for w in slabs.windows(2) {
            assert_eq!(w[0].hi[2], w[1].lo[2]);
            assert_eq!(w[0].hi[2] % cz, 0, "cut not on a cuboid boundary: {w:?}");
        }
        // Concatenated slab payloads are byte-identical to one read.
        let vol = hash_vol(bx);
        svc.write(0, 0, 0, bx, &vol).unwrap();
        let whole = svc.read::<u32>(0, 0, 0, bx).unwrap();
        let mut streamed: Vec<u8> = Vec::new();
        for s in &slabs {
            streamed.extend_from_slice(svc.read::<u32>(0, 0, 0, *s).unwrap().as_bytes());
        }
        assert_eq!(streamed, whole.as_bytes());
        // A budget larger than the request is a single slab.
        assert_eq!(svc.slab_boxes(0, bx, usize::MAX).unwrap(), vec![bx]);
        // A budget below one cuboid z-group falls back to thinner cuts
        // (hard memory bound beats cuboid alignment); payload identity
        // still holds, and no slab exceeds the budget by more than the
        // one-z-layer floor.
        let tight = svc.slab_boxes(0, bx, plane * 3).unwrap();
        assert!(tight.len() > slabs.len(), "{tight:?}");
        let mut tight_bytes: Vec<u8> = Vec::new();
        for s in &tight {
            assert!(s.extent()[2] <= 3, "slab over budget: {s:?}");
            tight_bytes.extend_from_slice(svc.read::<u32>(0, 0, 0, *s).unwrap().as_bytes());
        }
        assert_eq!(tight_bytes, whole.as_bytes());
        // Out-of-bounds requests fail up front.
        assert!(svc
            .slab_boxes(0, Box3::new([0, 0, 0], [300, 10, 10]), 1 << 20)
            .is_err());
    }

    #[test]
    fn write_then_read_identity_whole_volume() {
        let svc = service([256, 256, 32], 1);
        let bx = Box3::new([0, 0, 0], [256, 256, 32]);
        let vol = hash_vol(bx);
        svc.write(0, 0, 0, bx, &vol).unwrap();
        assert_eq!(svc.read::<u32>(0, 0, 0, bx).unwrap(), vol);
    }

    #[test]
    fn unwritten_region_reads_zero() {
        let svc = service([256, 256, 32], 1);
        let out = svc.read::<u32>(0, 0, 0, Box3::new([10, 20, 3], [100, 90, 9])).unwrap();
        assert!(out.all_zero());
    }

    #[test]
    fn arbitrary_cutout_matches_written_prop() {
        property("cutout_subbox_identity", 40, |g| {
            let dims = [160, 160, 48];
            let svc = service(dims, 1);
            let whole = Box3::new([0, 0, 0], dims);
            let vol = hash_vol(whole);
            svc.write(0, 0, 0, whole, &vol).unwrap();
            let (lo, hi) = g.boxed(dims, 90);
            let bx = Box3::new(lo, hi);
            let got = svc.read::<u32>(0, 0, 0, bx).unwrap();
            assert_eq!(got, vol.extract_box(bx));
        });
    }

    #[test]
    fn partial_writes_compose_prop() {
        // Two overlapping writes: later wins (overwrite merge).
        property("partial_writes_compose", 25, |g| {
            let dims = [128, 128, 32];
            let svc = service(dims, 1);
            let (alo, ahi) = g.boxed(dims, 60);
            let (blo, bhi) = g.boxed(dims, 60);
            let (a, b) = (Box3::new(alo, ahi), Box3::new(blo, bhi));
            let va = hash_vol(a);
            let mut vb = hash_vol(b);
            vb.map_in_place(|v| v ^ 0xdead_beef);
            svc.write(0, 0, 0, a, &va).unwrap();
            svc.write(0, 0, 0, b, &vb).unwrap();
            // Expected composite.
            let whole = Box3::new([0, 0, 0], dims);
            let mut expect = DenseVolume::<u32>::zeros(dims);
            expect.copy_box_from(&va, Box3::new([0, 0, 0], va.dims()), a.lo);
            expect.copy_box_from(&vb, Box3::new([0, 0, 0], vb.dims()), b.lo);
            assert_eq!(svc.read::<u32>(0, 0, 0, whole).unwrap(), expect);
        });
    }

    #[test]
    fn preserve_merge_keeps_existing() {
        let svc = service([128, 128, 16], 1);
        let bx = Box3::new([0, 0, 0], [64, 64, 16]);
        let mut first = DenseVolume::<u32>::zeros(bx.extent());
        first.fill_box(Box3::new([0, 0, 0], [32, 64, 16]), 7);
        svc.write(0, 0, 0, bx, &first).unwrap();
        let mut second = DenseVolume::<u32>::zeros(bx.extent());
        second.fill_box(Box3::new([0, 0, 0], [64, 64, 16]), 9);
        svc.write_with(0, 0, 0, bx, &second, |old, new| if old != 0 { old } else { new })
            .unwrap();
        let got = svc.read::<u32>(0, 0, 0, bx).unwrap();
        assert_eq!(got.get([0, 0, 0]), 7, "preserved");
        assert_eq!(got.get([40, 0, 0]), 9, "filled");
    }

    #[test]
    fn classify_alignment() {
        let svc = service([512, 512, 64], 1);
        let cshape = svc.store().cuboid_shape(0).unwrap();
        let aligned = Box3::at([cshape[0], 0, 0], cshape);
        assert_eq!(svc.classify(0, &aligned).unwrap(), Alignment::Aligned);
        let unaligned = Box3::new([1, 0, 0], [cshape[0], cshape[1], cshape[2]]);
        assert_eq!(svc.classify(0, &unaligned).unwrap(), Alignment::Unaligned);
    }

    #[test]
    fn out_of_bounds_rejected() {
        let svc = service([128, 128, 16], 1);
        assert!(svc.read::<u32>(0, 0, 0, Box3::new([0, 0, 0], [129, 1, 1])).is_err());
        assert!(svc.read::<u32>(3, 0, 0, Box3::new([0, 0, 0], [1, 1, 1])).is_err());
        assert!(svc.read::<u32>(0, 0, 5, Box3::new([0, 0, 0], [1, 1, 1])).is_err());
        assert!(svc.read::<u32>(0, 3, 0, Box3::new([0, 0, 0], [1, 1, 1])).is_err());
    }

    #[test]
    fn plane_projections_match_volume() {
        let svc = service([96, 96, 24], 1);
        let whole = Box3::new([0, 0, 0], [96, 96, 24]);
        let vol = hash_vol(whole);
        svc.write(0, 0, 0, whole, &vol).unwrap();
        let (w, h, xy) = svc.read_plane::<u32>(0, 0, 0, Plane::Xy(5), [8, 16], [40, 48]).unwrap();
        assert_eq!((w, h), (32, 32));
        assert_eq!(xy[0], vol.get([8, 16, 5]));
        assert_eq!(xy[(31 + 31 * 32) as usize], vol.get([39, 47, 5]));
        let (w, h, xz) = svc.read_plane::<u32>(0, 0, 0, Plane::Xz(10), [0, 0], [96, 24]).unwrap();
        assert_eq!((w, h), (96, 24));
        assert_eq!(xz[(5 + 3 * 96) as usize], vol.get([5, 10, 3]));
    }

    #[test]
    fn timeseries_distinct_per_t() {
        let ds =
            Arc::new(DatasetBuilder::new("ts", [64, 64, 8]).levels(1).timesteps(4).build());
        let pr = Arc::new(Project::annotation("ann", "ts"));
        let svc =
            CutoutService::new(Arc::new(CuboidStore::new(ds, pr, Arc::new(MemStore::new()))));
        let bx = Box3::new([0, 0, 0], [64, 64, 8]);
        for t in 0..4u64 {
            let mut v = DenseVolume::<u32>::zeros(bx.extent());
            v.fill_box(bx, (t + 1) as u32 * 100);
            svc.write(0, 0, t, bx, &v).unwrap();
        }
        let series = svc.read_timeseries::<u32>(0, 0, 0, 4, bx).unwrap();
        for (t, v) in series.iter().enumerate() {
            assert_eq!(v.get([0, 0, 0]), (t as u32 + 1) * 100);
        }
    }

    #[test]
    fn multichannel_separate_spaces() {
        let ds =
            Arc::new(DatasetBuilder::new("at", [64, 64, 8]).levels(1).channels(3).build());
        let pr = Arc::new(Project::image("img", "at").with_dtype(crate::core::Dtype::U16));
        let svc =
            CutoutService::new(Arc::new(CuboidStore::new(ds, pr, Arc::new(MemStore::new()))));
        let bx = Box3::new([0, 0, 0], [64, 64, 8]);
        for c in 0..3u16 {
            let mut v = DenseVolume::<u16>::zeros(bx.extent());
            v.fill_box(bx, (c + 1) * 10);
            svc.write(0, c, 0, bx, &v).unwrap();
        }
        for c in 0..3u16 {
            assert_eq!(svc.read::<u16>(0, c, 0, bx).unwrap().get([1, 1, 1]), (c + 1) * 10);
        }
    }

    #[test]
    fn read_your_writes_through_wal_overlay() {
        // A hot project's CutoutService runs over a WalEngine: writes sit
        // in the SSD log, and cutouts must merge the overlay over the
        // database node both before and after the flush.
        use crate::storage::Engine;
        use crate::wal::{Wal, WalConfig, WalEngine};
        let ds = Arc::new(DatasetBuilder::new("t", [160, 160, 48]).levels(1).build());
        let pr = Arc::new(Project::annotation("ann", "t"));
        let log: Engine = Arc::new(MemStore::new());
        let dest: Engine = Arc::new(MemStore::new());
        let cfg = WalConfig { background_flush: false, ..WalConfig::default() };
        let wal = Wal::open("ann", Arc::clone(&log), Arc::clone(&dest), cfg).unwrap();
        let engine: Engine = Arc::new(WalEngine::new(Arc::clone(&wal)));
        let svc = CutoutService::new(Arc::new(CuboidStore::new(ds, pr, engine)));

        let whole = Box3::new([0, 0, 0], [160, 160, 48]);
        let vol = hash_vol(whole);
        svc.write(0, 0, 0, whole, &vol).unwrap();
        assert!(wal.depth() > 0, "writes must land in the log");
        let bx = Box3::new([13, 27, 5], [90, 140, 41]);
        assert_eq!(svc.read::<u32>(0, 0, 0, bx).unwrap(), vol.extract_box(bx));

        // Same answer once the log has drained to the database node.
        wal.flush_now().unwrap();
        assert_eq!(svc.read::<u32>(0, 0, 0, bx).unwrap(), vol.extract_box(bx));

        // A post-flush RMW write reads base data and overlays the patch.
        let inner = Box3::new([30, 30, 4], [90, 90, 12]);
        let mut patch = DenseVolume::<u32>::zeros(inner.extent());
        patch.fill_box(Box3::new([0, 0, 0], inner.extent()), 777);
        svc.write(0, 0, 0, inner, &patch).unwrap();
        let got = svc.read::<u32>(0, 0, 0, whole).unwrap();
        assert_eq!(got.get([30, 30, 4]), 777);
        assert_eq!(got.get([29, 30, 4]), vol.get([29, 30, 4]));
    }

    #[test]
    fn parallel_read_matches_sequential_prop() {
        // The satellite property: 1-worker and 8-worker reads are
        // byte-identical across aligned, unaligned, and empty boxes.
        property("parallel_read_parity", 30, |g| {
            let dims = [160, 160, 48];
            let svc = service(dims, 1)
                .with_read_config(ReadConfig { parallel_threshold: 1, ..ReadConfig::default() });
            let whole = Box3::new([0, 0, 0], dims);
            let vol = hash_vol(whole);
            svc.write(0, 0, 0, whole, &vol).unwrap();
            let cshape = svc.store().cuboid_shape(0).unwrap();

            let (lo, hi) = g.boxed(dims, 120);
            let unaligned = Box3::new(lo, hi);
            let aligned = unaligned.align_outward(cshape).intersect(&whole);
            for bx in [unaligned, aligned] {
                let seq = svc.read_with_workers::<u32>(0, 0, 0, bx, 1).unwrap();
                let par = svc.read_with_workers::<u32>(0, 0, 0, bx, 8).unwrap();
                assert_eq!(seq.as_bytes(), par.as_bytes(), "box {bx:?}");
                assert_eq!(seq, vol.extract_box(bx), "box {bx:?} vs ground truth");
            }
            // Empty boxes are rejected identically on both paths.
            let empty = Box3::new(lo, lo);
            assert!(svc.read_with_workers::<u32>(0, 0, 0, empty, 1).is_err());
            assert!(svc.read_with_workers::<u32>(0, 0, 0, empty, 8).is_err());
            // A never-written region reads all-zero on both paths.
            let fresh = service(dims, 1)
                .with_read_config(ReadConfig { parallel_threshold: 1, ..ReadConfig::default() });
            let seq = fresh.read_with_workers::<u32>(0, 0, 0, unaligned, 1).unwrap();
            let par = fresh.read_with_workers::<u32>(0, 0, 0, unaligned, 8).unwrap();
            assert!(seq.all_zero());
            assert_eq!(seq, par);
        });
    }

    #[test]
    fn parallel_read_records_fanout_metrics() {
        let svc = service([256, 256, 32], 1)
            .with_read_config(ReadConfig { workers: 4, parallel_threshold: 2, batches_per_worker: 2 });
        let whole = Box3::new([0, 0, 0], [256, 256, 32]);
        let vol = hash_vol(whole);
        svc.write(0, 0, 0, whole, &vol).unwrap();
        assert_eq!(svc.read::<u32>(0, 0, 0, whole).unwrap(), vol);
        assert_eq!(svc.metrics.parallel_reads.get(), 1);
        assert!(svc.metrics.fanout_width.count() == 1);
        // A single-cuboid read stays sequential.
        let tiny = Box3::new([0, 0, 0], [8, 8, 8]);
        let _ = svc.read::<u32>(0, 0, 0, tiny).unwrap();
        assert!(svc.metrics.sequential_reads.get() >= 1);
    }

    #[test]
    fn batch_plan_is_shard_aligned_and_covers() {
        use crate::cluster::ShardedEngine;
        use crate::shard::ShardMap;
        use crate::storage::Engine;
        let ds = Arc::new(DatasetBuilder::new("t", [256, 256, 32]).levels(1).build());
        let pr = Arc::new(Project::annotation("ann", "t"));
        let engines: Vec<Engine> =
            (0..2).map(|_| Arc::new(MemStore::new()) as Engine).collect();
        let map = ShardMap::even(64, vec![0, 1]).unwrap();
        let engine: Engine = Arc::new(ShardedEngine::new(map.clone(), engines));
        let svc = CutoutService::new(Arc::new(CuboidStore::new(ds, pr, engine)))
            .with_read_config(ReadConfig { workers: 4, parallel_threshold: 1, batches_per_worker: 2 });
        let codes: Vec<u64> = (0..64).collect(); // spans the split at 32
        let batches = svc.plan_batches(&codes, 4);
        // Batches tile the code list in order...
        let mut cur = 0usize;
        for &(lo, hi) in &batches {
            assert_eq!(lo, cur);
            assert!(hi > lo);
            cur = hi;
        }
        assert_eq!(cur, codes.len());
        // ...and no batch straddles the shard boundary.
        for &(lo, hi) in &batches {
            let first = map.node_for(codes[lo]);
            assert!(
                codes[lo..hi].iter().all(|&c| map.node_for(c) == first),
                "batch {lo}..{hi} straddles shards"
            );
        }
    }

    #[test]
    fn timeseries_parallel_matches_sequential() {
        let ds = Arc::new(
            DatasetBuilder::new("ts", [64, 64, 8]).levels(1).timesteps(6).build(),
        );
        let pr = Arc::new(Project::annotation("ann", "ts"));
        let store = Arc::new(CuboidStore::new(ds, pr, Arc::new(MemStore::new())));
        let par = CutoutService::new(Arc::clone(&store))
            .with_read_config(ReadConfig { workers: 4, ..ReadConfig::default() });
        let seq = CutoutService::new(store).with_read_config(ReadConfig::sequential());
        let bx = Box3::new([3, 5, 1], [50, 60, 7]);
        for t in 0..6u64 {
            let mut v = DenseVolume::<u32>::zeros(bx.extent());
            v.fill_box(Box3::new([0, 0, 0], bx.extent()), (t + 1) as u32 * 11);
            par.write(0, 0, t, bx, &v).unwrap();
        }
        let a = par.read_timeseries::<u32>(0, 0, 0, 6, bx).unwrap();
        let b = seq.read_timeseries::<u32>(0, 0, 0, 6, bx).unwrap();
        assert_eq!(a, b);
        assert_eq!(a[3].get([0, 0, 0]), 44);
    }

    /// Apply one of the three merge disciplines the engine must keep
    /// byte-identical across fan-out widths: overwrite (the elision
    /// path), preserve, and an exception-style xor merge.
    fn apply_discipline(svc: &CutoutService, d: usize, bx: Box3, patch: &DenseVolume<u32>) {
        match d {
            0 => svc.write(0, 0, 0, bx, patch).unwrap(),
            1 => svc
                .write_with(0, 0, 0, bx, patch, |old, new| if old != 0 { old } else { new })
                .unwrap(),
            _ => svc.write_with(0, 0, 0, bx, patch, |old, new| old ^ new).unwrap(),
        }
    }

    #[test]
    fn parallel_write_matches_sequential_prop() {
        // The tentpole property: 1-worker and 8-worker writes are
        // byte-identical across aligned, unaligned, and empty boxes for
        // every merge discipline.
        property("parallel_write_parity", 10, |g| {
            let dims = [128, 128, 32];
            let whole = Box3::new([0, 0, 0], dims);
            let base = hash_vol(whole);
            let (lo, hi) = g.boxed(dims, 100);
            let unaligned = Box3::new(lo, hi);
            let mut patch_whole = hash_vol(whole);
            patch_whole.map_in_place(|v| v ^ 0x5a5a_5a5a);
            for d in 0..3usize {
                let seq = service(dims, 1).with_write_config(WriteConfig {
                    workers: 1,
                    parallel_threshold: 1,
                    ..WriteConfig::default()
                });
                let par = service(dims, 1).with_write_config(WriteConfig {
                    workers: 8,
                    parallel_threshold: 1,
                    ..WriteConfig::default()
                });
                let cshape = seq.store().cuboid_shape(0).unwrap();
                let aligned = unaligned.align_outward(cshape).intersect(&whole);
                for bx in [unaligned, aligned] {
                    let patch = patch_whole.extract_box(bx);
                    for svc in [&seq, &par] {
                        // Identical seed state through the sequential path.
                        svc.write_with_workers(0, 0, 0, whole, &base, 1).unwrap();
                    }
                    apply_discipline(&seq, d, bx, &patch);
                    apply_discipline(&par, d, bx, &patch);
                    let a = seq.read_with_workers::<u32>(0, 0, 0, whole, 1).unwrap();
                    let b = par.read_with_workers::<u32>(0, 0, 0, whole, 1).unwrap();
                    assert_eq!(a.as_bytes(), b.as_bytes(), "discipline {d} box {bx:?}");
                }
                // Empty boxes are rejected identically on both paths.
                let empty = Box3::new(lo, lo);
                let zvol = DenseVolume::<u32>::zeros(empty.extent());
                assert!(seq.write(0, 0, 0, empty, &zvol).is_err());
                assert!(par.write(0, 0, 0, empty, &zvol).is_err());
            }
        });
    }

    #[test]
    fn aligned_overwrite_elides_existing_reads() {
        // Fully covered cuboids under overwrite never read: the engine
        // sees zero read traffic for a cuboid-aligned bulk write.
        let svc = service([256, 256, 32], 1).with_write_config(WriteConfig {
            workers: 4,
            parallel_threshold: 1,
            ..WriteConfig::default()
        });
        let whole = Box3::new([0, 0, 0], [256, 256, 32]);
        let vol = hash_vol(whole);
        svc.write(0, 0, 0, whole, &vol).unwrap();
        let covered = whole.cuboid_cover(svc.store().cuboid_shape(0).unwrap()).volume();
        assert_eq!(svc.write_metrics.rmw_reads.get(), 0, "aligned overwrite must not read");
        assert_eq!(svc.write_metrics.elided_reads.get(), covered);
        assert_eq!(svc.write_metrics.parallel_writes.get(), 1);
        assert_eq!(svc.write_metrics.fanout_width.count(), 1);
        let s = svc.store().engine().stats().snapshot();
        assert_eq!(s.reads + s.run_reads + s.misses, 0, "engine saw read traffic");
        assert_eq!(svc.read::<u32>(0, 0, 0, whole).unwrap(), vol);

        // An unaligned overwrite pays RMW only on boundary cuboids.
        let inner = Box3::new([1, 1, 1], [255, 255, 31]);
        let patch = hash_vol(inner);
        svc.write(0, 0, 0, inner, &patch).unwrap();
        assert!(svc.write_metrics.rmw_reads.get() > 0, "boundary cuboids must pre-read");
        let got = svc.read::<u32>(0, 0, 0, whole).unwrap();
        assert_eq!(got.get([0, 0, 0]), vol.get([0, 0, 0]), "outside patch preserved");
        assert_eq!(got.get([1, 1, 1]), patch.get([0, 0, 0]));

        // A merge write (discipline) can never elide.
        svc.write_with(0, 0, 0, whole, &vol, |old, new| if old != 0 { old } else { new })
            .unwrap();
        assert_eq!(svc.write_metrics.rmw_reads.get() % covered, 0); // all covered cuboids read
    }

    #[test]
    fn concurrent_parallel_writes_and_reads_stay_cache_coherent() {
        // A parallel writer and concurrent readers over a cached store:
        // readers may see a torn mix ACROSS cuboids (commits are
        // per-batch), but never a stale cuboid after its invalidation —
        // and once the writer joins, the final round is fully visible.
        use crate::chunkstore::{CacheConfig, CuboidCache};
        let ds = Arc::new(DatasetBuilder::new("t", [256, 256, 32]).levels(1).build());
        let pr = Arc::new(Project::annotation("ann", "t"));
        let cache = Arc::new(CuboidCache::new(CacheConfig::default()));
        let store =
            Arc::new(CuboidStore::new(ds, pr, Arc::new(MemStore::new())).with_cache(cache));
        let svc = CutoutService::new(store)
            .with_read_config(ReadConfig {
                workers: 4,
                parallel_threshold: 1,
                ..ReadConfig::default()
            })
            .with_write_config(WriteConfig {
                workers: 4,
                parallel_threshold: 1,
                ..WriteConfig::default()
            });
        let whole = Box3::new([0, 0, 0], [256, 256, 32]);
        const ROUNDS: u32 = 6;
        let cshape = svc.store().cuboid_shape(0).unwrap();
        std::thread::scope(|s| {
            let svc = &svc;
            let writer = s.spawn(move || {
                for r in 1..=ROUNDS {
                    let mut v = DenseVolume::<u32>::zeros(whole.extent());
                    v.fill_box(Box3::new([0, 0, 0], whole.extent()), r);
                    svc.write(0, 0, 0, whole, &v).unwrap();
                }
            });
            while !writer.is_finished() {
                let got = svc.read::<u32>(0, 0, 0, whole).unwrap();
                // Each cuboid blob is replaced atomically, so its voxels
                // must be uniform and within the written range.
                for cz in 0..whole.hi[2] / cshape[2] {
                    for cy in 0..whole.hi[1] / cshape[1] {
                        for cx in 0..whole.hi[0] / cshape[0] {
                            let lo = [cx * cshape[0], cy * cshape[1], cz * cshape[2]];
                            let a = got.get(lo);
                            let b = got.get([
                                lo[0] + cshape[0] - 1,
                                lo[1] + cshape[1] - 1,
                                lo[2] + cshape[2] - 1,
                            ]);
                            assert_eq!(a, b, "torn cuboid at {lo:?}");
                            assert!(a <= ROUNDS, "impossible value {a}");
                        }
                    }
                }
            }
            writer.join().unwrap();
        });
        let fin = svc.read::<u32>(0, 0, 0, whole).unwrap();
        assert_eq!(fin.count_eq(ROUNDS), whole.volume(), "stale cuboid after final write");
    }

    #[test]
    fn write_status_snapshots_config_and_counters() {
        let svc = service([128, 128, 16], 1).with_write_config(WriteConfig {
            workers: 3,
            parallel_threshold: 1,
            ..WriteConfig::default()
        });
        let bx = Box3::new([0, 0, 0], [128, 128, 16]);
        svc.write(0, 0, 0, bx, &hash_vol(bx)).unwrap();
        let st = svc.write_status();
        assert_eq!(st.workers, 3);
        assert_eq!(st.sequential_writes + st.parallel_writes, 1);
        assert_eq!(st.elided_reads, 1); // 128x128x16 = exactly one cuboid
        // The live knob: retune and observe.
        svc.set_write_config(WriteConfig::with_workers(5));
        assert_eq!(svc.write_status().workers, 5);
    }

    #[test]
    fn rmw_write_noise_immune() {
        // Unaligned write must not clobber neighbours within shared cuboids.
        let svc = service([128, 128, 16], 1);
        let whole = Box3::new([0, 0, 0], [128, 128, 16]);
        let base = hash_vol(whole);
        svc.write(0, 0, 0, whole, &base).unwrap();
        let inner = Box3::new([30, 30, 4], [90, 90, 12]);
        let mut patch = DenseVolume::<u32>::zeros(inner.extent());
        patch.fill_box(Box3::new([0, 0, 0], inner.extent()), u32::MAX);
        svc.write(0, 0, 0, inner, &patch).unwrap();
        let got = svc.read::<u32>(0, 0, 0, whole).unwrap();
        assert_eq!(got.get([29, 30, 4]), base.get([29, 30, 4]));
        assert_eq!(got.get([30, 30, 4]), u32::MAX);
        assert_eq!(got.get([89, 89, 11]), u32::MAX);
        assert_eq!(got.get([90, 89, 11]), base.get([90, 89, 11]));
    }
}
