//! Typed client for the OCP Web services — what a vision pipeline or an
//! analysis script links against. Wraps the HTTP wire protocol of
//! [`crate::web`]; the paper's clients did the same over HDF5 from
//! "Java, C/C++, Python, Perl, php, and Matlab" (§4.2).
//!
//! Every call rides [`crate::web::http::request`], so connections are
//! pooled keep-alive sockets (reused across sequential calls, retried
//! once on staleness) and chunked (streamed) responses are reassembled
//! transparently.

use crate::annotation::RamonObject;
use crate::array::DenseVolume;
use crate::core::{Box3, Vec3, WriteDiscipline};
use crate::web::http::{request, request_with, RequestOpts, RetryPolicy};
use crate::web::ocpk;
use crate::{Error, Result};

/// HTTP client bound to one server and project token.
pub struct OcpClient {
    base: String,
    token: String,
    opts: RequestOpts,
}

impl OcpClient {
    pub fn new(base_url: &str, token: &str) -> Self {
        OcpClient {
            base: base_url.trim_end_matches('/').to_string(),
            token: token.to_string(),
            opts: RequestOpts::default(),
        }
    }

    /// Opt in to throttle retries: 429/503 answers are re-issued under
    /// `policy` (capped exponential backoff with full jitter, floored
    /// at the server's `Retry-After`). Idempotent calls only — the
    /// transport never replays a POST.
    pub fn with_retry(mut self, policy: RetryPolicy) -> Self {
        self.opts.retry = Some(policy);
        self
    }

    /// Send `X-OCPD-Deadline-Ms: ms` on every call: the server abandons
    /// remaining batch work and answers 504 once the budget expires.
    pub fn with_deadline_ms(mut self, ms: u64) -> Self {
        self.opts.deadline_ms = Some(ms);
        self
    }

    fn check(status: u16, retry_after: Option<u64>, body: Vec<u8>) -> Result<Vec<u8>> {
        if status == 200 {
            Ok(body)
        } else {
            let msg = String::from_utf8_lossy(&body).to_string();
            Err(match status {
                404 => Error::NotFound(msg),
                400 => Error::BadRequest(msg),
                429 | 503 if retry_after.is_some() => Error::Throttled {
                    retry_after_ms: retry_after.unwrap_or(1).saturating_mul(1000),
                },
                504 => Error::DeadlineExceeded(msg),
                _ => Error::Other(format!("http {status}: {msg}")),
            })
        }
    }

    fn get(&self, path: &str) -> Result<Vec<u8>> {
        let info = request_with("GET", &format!("{}{path}", self.base), &[], &self.opts)?;
        Self::check(info.status, info.retry_after, info.body)
    }

    fn put(&self, path: &str, body: &[u8]) -> Result<Vec<u8>> {
        let info = request_with("PUT", &format!("{}{path}", self.base), body, &self.opts)?;
        Self::check(info.status, info.retry_after, info.body)
    }

    /// Image cutout (Table 1's first row).
    pub fn cutout_u8(&self, res: u32, bx: Box3) -> Result<DenseVolume<u8>> {
        let body = self.get(&format!(
            "/{}/ocpk/{res}/{},{}/{},{}/{},{}/",
            self.token, bx.lo[0], bx.hi[0], bx.lo[1], bx.hi[1], bx.lo[2], bx.hi[2]
        ))?;
        Ok(ocpk::decode_volume::<u8>(&body)?.2)
    }

    /// Annotation cutout.
    pub fn cutout_u32(&self, res: u32, bx: Box3) -> Result<DenseVolume<u32>> {
        let body = self.get(&format!(
            "/{}/ocpk/{res}/{},{}/{},{}/{},{}/",
            self.token, bx.lo[0], bx.hi[0], bx.lo[1], bx.hi[1], bx.lo[2], bx.hi[2]
        ))?;
        Ok(ocpk::decode_volume::<u32>(&body)?.2)
    }

    /// Upload an image block.
    pub fn write_image(&self, res: u32, lo: Vec3, vol: &DenseVolume<u8>) -> Result<()> {
        let body = ocpk::encode_volume(crate::core::Dtype::U8, lo, vol)?;
        self.put(&format!("/{}/image/{res}/", self.token), &body)?;
        Ok(())
    }

    /// Write an annotation volume under a discipline.
    pub fn write_annotation(
        &self,
        res: u32,
        lo: Vec3,
        vol: &DenseVolume<u32>,
        discipline: WriteDiscipline,
    ) -> Result<String> {
        let disc = match discipline {
            WriteDiscipline::Overwrite => "overwrite",
            WriteDiscipline::Preserve => "preserve",
            WriteDiscipline::Exception => "exception",
        };
        let body = ocpk::encode_volume(crate::core::Dtype::U32, lo, vol)?;
        let resp = self.put(&format!("/{}/{disc}/{res}/", self.token), &body)?;
        Ok(String::from_utf8_lossy(&resp).to_string())
    }

    /// Batch-write RAMON objects; returns assigned ids.
    pub fn put_objects(&self, objs: &[RamonObject]) -> Result<Vec<u32>> {
        let resp = self.put(&format!("/{}/ramon/", self.token), &ocpk::encode_objects(objs))?;
        String::from_utf8_lossy(&resp)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.parse().map_err(|_| Error::Other(format!("bad id '{s}'"))))
            .collect()
    }

    /// Batch metadata read.
    pub fn get_objects(&self, ids: &[u32]) -> Result<Vec<RamonObject>> {
        let path = format!(
            "/{}/{}/",
            self.token,
            ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
        );
        ocpk::decode_objects(&self.get(&path)?)
    }

    /// Object voxel list.
    pub fn voxels(&self, id: u32) -> Result<Vec<Vec3>> {
        ocpk::decode_voxels(&self.get(&format!("/{}/{id}/voxels/", self.token))?)
    }

    /// Object bounding box.
    pub fn bounding_box(&self, id: u32) -> Result<Box3> {
        let text = String::from_utf8_lossy(
            &self.get(&format!("/{}/{id}/boundingbox/", self.token))?,
        )
        .to_string();
        let parts: Vec<u64> = text
            .split(['/', ','])
            .map(|s| s.parse().map_err(|_| Error::Other(format!("bad bbox '{text}'"))))
            .collect::<Result<_>>()?;
        if parts.len() != 6 {
            return Err(Error::Other(format!("bad bbox '{text}'")));
        }
        Ok(Box3::new([parts[0], parts[2], parts[4]], [parts[1], parts[3], parts[5]]))
    }

    /// Dense object read, optionally restricted.
    pub fn object_cutout(&self, id: u32, region: Option<(u32, Box3)>) -> Result<(Box3, DenseVolume<u32>)> {
        let path = match region {
            None => format!("/{}/{id}/cutout/", self.token),
            Some((res, b)) => format!(
                "/{}/{id}/cutout/{res}/{},{}/{},{}/{},{}/",
                self.token, b.lo[0], b.hi[0], b.lo[1], b.hi[1], b.lo[2], b.hi[2]
            ),
        };
        let (_, bx, vol) = ocpk::decode_volume::<u32>(&self.get(&path)?)?;
        Ok((bx, vol))
    }

    /// Predicate query; `preds` are URL segments, e.g.
    /// `&["type", "synapse", "confidence", "geq", "0.99"]`.
    pub fn query(&self, preds: &[&str]) -> Result<Vec<u32>> {
        let resp = self.get(&format!("/{}/objects/{}/", self.token, preds.join("/")))?;
        let text = String::from_utf8_lossy(&resp);
        text.split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.parse().map_err(|_| Error::Other(format!("bad id '{s}'"))))
            .collect()
    }

    /// Fetch a stored-layout tile.
    pub fn tile(&self, res: u32, z: u64, y: u64, x: u64) -> Result<Vec<u8>> {
        self.get(&format!("/{}/tile/{res}/{z}/{y}_{x}.gray", self.token))
    }
}

/// Cluster-wide (token-free) info.
pub fn cluster_info(base_url: &str) -> Result<String> {
    let (s, b) = request("GET", &format!("{}/info/", base_url.trim_end_matches('/')), &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}")));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Status of every hot project's write-ahead log.
pub fn wal_status(base_url: &str) -> Result<String> {
    let (s, b) =
        request("GET", &format!("{}/wal/status/", base_url.trim_end_matches('/')), &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}")));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Transport status: request/connection counters, reuse ratio,
/// in-flight gauge, admission rejections, per-route latency.
pub fn http_status(base_url: &str) -> Result<String> {
    let (s, b) =
        request("GET", &format!("{}/http/status/", base_url.trim_end_matches('/')), &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// The unified Prometheus-text metrics exposition (`GET /metrics/`):
/// every subsystem's counters, gauges, and histograms in one scrape.
pub fn metrics(base_url: &str) -> Result<String> {
    let (s, b) = request("GET", &format!("{}/metrics/", base_url.trim_end_matches('/')), &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}")));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Tracer status: mode, sampling, retention counters, ring occupancy.
pub fn trace_status(base_url: &str) -> Result<String> {
    let (s, b) =
        request("GET", &format!("{}/trace/status/", base_url.trim_end_matches('/')), &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Sampled recent traces as indented span trees, newest first.
pub fn trace_recent(base_url: &str) -> Result<String> {
    let (s, b) =
        request("GET", &format!("{}/trace/recent/", base_url.trim_end_matches('/')), &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}")));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Traces above the slow threshold, newest first.
pub fn trace_slow(base_url: &str) -> Result<String> {
    let (s, b) =
        request("GET", &format!("{}/trace/slow/", base_url.trim_end_matches('/')), &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}")));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Status of every project's cuboid cache (entries, bytes, hit rate).
pub fn cache_status(base_url: &str) -> Result<String> {
    let (s, b) =
        request("GET", &format!("{}/cache/status/", base_url.trim_end_matches('/')), &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}")));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Status of every project's write engine (fan-out width, elided vs RMW
/// pre-reads, merge latency).
pub fn write_status(base_url: &str) -> Result<String> {
    let (s, b) =
        request("GET", &format!("{}/write/status/", base_url.trim_end_matches('/')), &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Retune every project's write fan-out width. Returns the server's
/// `workers=N projects=K` report.
pub fn set_write_workers(base_url: &str, workers: usize) -> Result<String> {
    let url = format!("{}/write/workers/{workers}/", base_url.trim_end_matches('/'));
    let (s, b) = request("PUT", &url, &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Drain write-ahead logs into their database nodes: all of them, or one
/// project's. Returns the server's `flushed=N` report.
pub fn wal_flush(base_url: &str, token: Option<&str>) -> Result<String> {
    let base = base_url.trim_end_matches('/');
    let url = match token {
        Some(t) => format!("{base}/wal/flush/{t}/"),
        None => format!("{base}/wal/flush/"),
    };
    let (s, b) = request("PUT", &url, &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Shard heat ranking and top hot key ranges (`GET /heat/status/`).
pub fn heat_status(base_url: &str) -> Result<String> {
    let (s, b) =
        request("GET", &format!("{}/heat/status/", base_url.trim_end_matches('/')), &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Per-tenant request/byte/worker-second ledgers (`GET /account/status/`).
pub fn account_status(base_url: &str) -> Result<String> {
    let (s, b) =
        request("GET", &format!("{}/account/status/", base_url.trim_end_matches('/')), &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Latency-objective attainment and error-budget burn per route class
/// (`GET /slo/status/`).
pub fn slo_status(base_url: &str) -> Result<String> {
    let (s, b) =
        request("GET", &format!("{}/slo/status/", base_url.trim_end_matches('/')), &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Cluster health: node liveness, replica-set epochs/leaders/lag, and
/// failover counters (`GET /cluster/status/`).
pub fn cluster_status(base_url: &str) -> Result<String> {
    let (s, b) =
        request("GET", &format!("{}/cluster/status/", base_url.trim_end_matches('/')), &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Force a leader promotion on one project shard. Returns the server's
/// `promoted: ...` report.
pub fn cluster_failover(base_url: &str, token: &str, shard: usize) -> Result<String> {
    let url = format!("{}/cluster/failover/{token}/{shard}/", base_url.trim_end_matches('/'));
    let (s, b) = request("POST", &url, &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Shard maps, move windows, and split-planner counters
/// (`GET /shards/status/`).
pub fn shards_status(base_url: &str) -> Result<String> {
    let (s, b) =
        request("GET", &format!("{}/shards/status/", base_url.trim_end_matches('/')), &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Split one project shard at its heat median and rehome the hot half.
/// Returns the server's `split: ...` report.
pub fn shards_split(base_url: &str, token: &str, shard: usize) -> Result<String> {
    let url = format!("{}/shards/split/{token}/{shard}/", base_url.trim_end_matches('/'));
    let (s, b) = request("POST", &url, &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Toggle the heat-driven auto splitter (`PUT /shards/auto/{on|off}/`).
pub fn shards_auto(base_url: &str, mode: &str) -> Result<String> {
    let url = format!("{}/shards/auto/{mode}/", base_url.trim_end_matches('/'));
    let (s, b) = request("PUT", &url, &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Submit a batch compute job. `spec` is the submit path after `/jobs/`
/// (e.g. `propagate/synapses_v0` or `synapse/synth/synapses_v0`);
/// `params` is the whitespace-separated `key=value` body (`workers=N`,
/// `job=ID` to resume, `dims=X,Y,Z` for ingest, ...). Returns the
/// server's `id=N name=... state=...` report.
pub fn submit_job(base_url: &str, spec: &str, params: &str) -> Result<String> {
    let url = format!(
        "{}/jobs/{}/",
        base_url.trim_end_matches('/'),
        spec.trim_matches('/')
    );
    let (s, b) = request("POST", &url, params.as_bytes())?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Status of every job, or one job by id.
pub fn job_status(base_url: &str, id: Option<u64>) -> Result<String> {
    let base = base_url.trim_end_matches('/');
    let url = match id {
        Some(id) => format!("{base}/jobs/status/{id}/"),
        None => format!("{base}/jobs/status/"),
    };
    let (s, b) = request("GET", &url, &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Cancel a job. The checkpoint journal survives, so resubmitting the
/// id (`job=ID`) resumes from the last completed block.
pub fn cancel_job(base_url: &str, id: u64) -> Result<String> {
    let url = format!("{}/jobs/cancel/{id}/", base_url.trim_end_matches('/'));
    let (s, b) = request("POST", &url, &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// QoS admission/fair-sharing status: enforcement state, in-flight
/// accounting, pool-gate queues, and per-tenant quota and token levels
/// (`GET /qos/status/`).
pub fn qos_status(base_url: &str) -> Result<String> {
    let (s, b) =
        request("GET", &format!("{}/qos/status/", base_url.trim_end_matches('/')), &[])?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Set one tenant's QoS quota. `params` is the whitespace-separated
/// `key=value` body (`req_per_s=F bytes_per_s=F weight=N`; rates may
/// be `inf`). Returns the server's `quota TOKEN: ...` echo.
pub fn qos_set_quota(base_url: &str, token: &str, params: &str) -> Result<String> {
    let url = format!("{}/qos/quota/{token}/", base_url.trim_end_matches('/'));
    let (s, b) = request("PUT", &url, params.as_bytes())?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}

/// Toggle QoS enforcement (`mode` is `on`/`off`); `high_water`, when
/// given, retunes the overload-shed threshold in in-flight bytes.
pub fn qos_enforce(base_url: &str, mode: &str, high_water: Option<u64>) -> Result<String> {
    let url = format!("{}/qos/enforce/{mode}/", base_url.trim_end_matches('/'));
    let body = match high_water {
        Some(hw) => format!("high_water={hw}"),
        None => String::new(),
    };
    let (s, b) = request("PUT", &url, body.as_bytes())?;
    if s != 200 {
        return Err(Error::Other(format!("http {s}: {}", String::from_utf8_lossy(&b))));
    }
    Ok(String::from_utf8_lossy(&b).to_string())
}
